//! The search [`Index`]: everything the cascade needs about a train
//! set, built once and shared (cheaply clonable behind `Arc`) across
//! queries, worker threads and the coordinator registry.
//!
//! Cached per train series:
//! * its values (optionally z-normalized once, so per-query work never
//!   re-normalizes the train side),
//! * its warping envelope (Lemire streaming min/max, O(T)) at the
//!   radius that covers the DP's reachable off-diagonal cells.

use std::sync::Arc;

use crate::data::LabeledSet;
use crate::error::{Error, Result};
use crate::measures::lb_keogh::envelope_into;
use crate::measures::sakoe_chiba::SakoeChibaDtw;
use crate::measures::spec::{GridResolver, MeasureSpec};
use crate::measures::workspace::{self, DpWorkspace};
use crate::pool;
use crate::search::early::{dtw_banded_ea_into, spdtw_ea_into, EaResult};
use crate::search::lanes::{dtw_banded_ea_lanes_into, spdtw_ea_lanes_into, MAX_LANES};
use crate::sparse::LocMatrix;

/// Prebuilt per-train-set state for cascade k-NN search.
#[derive(Clone, Debug)]
pub struct Index {
    /// Series length (all series and queries must match).
    pub t: usize,
    /// Envelope radius: covers every off-diagonal cell the DP may visit.
    pub radius: usize,
    /// Band passed to the banded-DTW kernel (`usize::MAX` = unbounded).
    pub band: usize,
    /// Train series values (z-normalized iff [`Self::znormalized`]).
    pub series: Vec<Vec<f64>>,
    /// Train labels, parallel to `series`.
    pub labels: Vec<usize>,
    /// Per-series (upper, lower) envelopes at [`Self::radius`].
    pub envs: Vec<(Vec<f64>, Vec<f64>)>,
    /// When set, full evaluations run early-abandoning SP-DTW over this
    /// grid instead of banded DTW.
    pub loc: Option<Arc<LocMatrix>>,
    /// Whether the envelope lower bounds are admissible for the DP in
    /// use.  Always true for banded DTW; for SP-DTW it requires every
    /// retained cell weight ≥ 1 (`f(p) = p^-γ` with γ ≥ 0 guarantees
    /// it).  When false the engine skips the LB stages and relies on
    /// early abandoning alone.
    pub lb_valid: bool,
    /// Stored series were z-normalized at build time; queries get the
    /// same treatment at query time.
    pub znormalized: bool,
}

impl Index {
    /// Index for banded-DTW search.  `band = usize::MAX` (or ≥ T)
    /// searches under unconstrained DTW.
    pub fn build(train: &LabeledSet, band: usize, threads: usize) -> Index {
        let t = train.series_len();
        let radius = if band >= t { t.saturating_sub(1) } else { band };
        Self::build_inner(train, radius, band, None, true, false, threads)
    }

    /// Like [`Self::build`] but stores z-normalized series and
    /// z-normalizes queries before searching.
    pub fn build_znormalized(train: &LabeledSet, band: usize, threads: usize) -> Index {
        let t = train.series_len();
        let radius = if band >= t { t.saturating_sub(1) } else { band };
        Self::build_inner(train, radius, band, None, true, true, threads)
    }

    /// Index for SP-DTW search over a learned LOC grid: the envelope
    /// radius shrinks to the grid's widest off-diagonal reach, and the
    /// LB stages stay enabled only if every cell weight is ≥ 1.
    pub fn build_spdtw(train: &LabeledSet, loc: Arc<LocMatrix>, threads: usize) -> Index {
        let t = train.series_len();
        assert_eq!(loc.t, t, "LOC grid T={} != series length {t}", loc.t);
        let radius = loc.max_band_offset();
        let lb_valid = loc.min_weight() >= 1.0 - 1e-12;
        Self::build_inner(train, radius, usize::MAX, Some(loc), lb_valid, false, threads)
    }

    /// Build the index a [`MeasureSpec`] asks for — the one spec-driven
    /// entrypoint the CLI, `SearchConfig` and the TCP v2
    /// `register_index` op all share.  Searchable specs are the DTW
    /// family the engine's DP stage can evaluate: `dtw`, `banded_dtw`,
    /// `sakoe_chiba` (its percentage band resolves against this train
    /// set's length) and `spdtw` (grid resolved through `grids`).
    /// Anything else is a typed error, and `znormalize` is banded-DTW
    /// only — both rejected here, at the boundary.
    pub fn build_from_spec(
        train: &LabeledSet,
        spec: &MeasureSpec,
        znormalize: bool,
        grids: &dyn GridResolver,
        threads: usize,
    ) -> Result<Index> {
        spec.validate()?;
        if train.is_empty() || train.series_len() == 0 {
            return Err(Error::config("cannot index an empty train set"));
        }
        let t = train.series_len();
        let band = match spec {
            MeasureSpec::Dtw => usize::MAX,
            MeasureSpec::BandedDtw { band_cells } => *band_cells,
            MeasureSpec::SakoeChiba { band_pct } => SakoeChibaDtw::new(*band_pct).band_for(t),
            MeasureSpec::SpDtw { grid } => {
                if znormalize {
                    return Err(Error::config(
                        "z-normalized indexes are banded-DTW only (not spdtw)",
                    ));
                }
                let loc = grids.resolve(grid)?;
                if loc.t != t {
                    return Err(Error::config(format!(
                        "grid T={} != train series length {t}",
                        loc.t
                    )));
                }
                return Ok(Self::build_spdtw(train, loc, threads));
            }
            other => {
                return Err(Error::config(format!(
                    "measure '{}' is not searchable: the k-NN engine evaluates banded DTW \
                     or SP-DTW",
                    other.name()
                )))
            }
        };
        Ok(if znormalize {
            Self::build_znormalized(train, band, threads)
        } else {
            Self::build(train, band, threads)
        })
    }

    fn build_inner(
        train: &LabeledSet,
        radius: usize,
        band: usize,
        loc: Option<Arc<LocMatrix>>,
        lb_valid: bool,
        znormalize: bool,
        threads: usize,
    ) -> Index {
        assert!(!train.is_empty(), "cannot index an empty train set");
        let t = train.series_len();
        assert!(t > 0, "cannot index zero-length series");
        let series: Vec<Vec<f64>> = train
            .series
            .iter()
            .map(|s| {
                if znormalize {
                    s.znormalized().values
                } else {
                    s.values.clone()
                }
            })
            .collect();
        let labels: Vec<usize> = train.series.iter().map(|s| s.label).collect();
        let envs = pool::par_map_ws(series.len(), threads, 1, |i, ws| {
            let mut upper = Vec::new();
            let mut lower = Vec::new();
            envelope_into(
                &series[i],
                radius,
                &mut upper,
                &mut lower,
                &mut ws.maxq,
                &mut ws.minq,
            );
            (upper, lower)
        });
        Index {
            t,
            radius,
            band,
            series,
            labels,
            envs,
            loc,
            lb_valid,
            znormalized: znormalize,
        }
    }

    /// Number of indexed train series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Exhaustive DP cells one comparison would cost without any
    /// pruning — the per-candidate unit of the brute-force baseline.
    pub fn full_eval_cells(&self) -> u64 {
        match &self.loc {
            Some(loc) => loc.nnz() as u64,
            None => crate::measures::sakoe_chiba::band_cells(self.t, self.band.min(self.t)),
        }
    }

    /// Early-abandoning full evaluation of `query` against candidate
    /// `j` under upper bound `ub` (INFINITY = exhaustive).
    pub fn full_eval(&self, query: &[f64], j: usize, ub: f64) -> EaResult {
        workspace::with_tls(|ws| self.full_eval_with(ws, query, j, ub))
    }

    /// [`Self::full_eval`] against caller-provided scratch — the
    /// engine's candidate loop threads one workspace through every DP.
    pub fn full_eval_with(
        &self,
        ws: &mut DpWorkspace,
        query: &[f64],
        j: usize,
        ub: f64,
    ) -> EaResult {
        match &self.loc {
            Some(loc) => spdtw_ea_into(ws, loc, query, &self.series[j], ub),
            None => dtw_banded_ea_into(ws, query, &self.series[j], self.band, ub),
        }
    }

    /// Lane-batched [`Self::full_eval_with`]: evaluate candidates `js`
    /// (1..=[`MAX_LANES`] of them) against `query` in lockstep, each
    /// under its own upper bound.  `out[l]` is bit-identical to
    /// `full_eval_with(ws, query, js[l], ubs[l])` — the lane kernels
    /// replicate the scalar per-lane FP op order exactly
    /// ([`crate::search::lanes`]).
    pub fn full_eval_lanes_with(
        &self,
        ws: &mut DpWorkspace,
        query: &[f64],
        js: &[usize],
        ubs: &[f64],
        out: &mut [EaResult],
    ) {
        let mut ys: [&[f64]; MAX_LANES] = [&[]; MAX_LANES];
        for (y, &j) in ys.iter_mut().zip(js) {
            *y = &self.series[j];
        }
        let ys = &ys[..js.len()];
        match &self.loc {
            Some(loc) => spdtw_ea_lanes_into(ws, loc, query, ys, ubs, out),
            None => dtw_banded_ea_lanes_into(ws, query, ys, self.band, ubs, out),
        }
    }

    /// FNV-1a-64 content hash of the indexed payload: `t`, the label
    /// sequence and every stored series' IEEE-754 bit pattern, in
    /// order.  Envelopes are derived state and excluded.  The TCP
    /// `register_index` op replies with this so a client re-submitting
    /// a known name can detect that the registered index was built from
    /// *different* data (drift) instead of silently searching a stale
    /// index — compare against [`content_hash_of`] over the submitted
    /// train set.  Note the hash covers the *stored* representation:
    /// a z-normalized index hashes its normalized series.
    pub fn content_hash(&self) -> u64 {
        content_hash_of(self.t, &self.labels, self.series.iter().map(Vec::as_slice))
    }

    /// Approximate resident size (bytes) — reported in the TCP
    /// `register_index` reply and the `spdtw index` CLI.
    ///
    /// Counts everything reachable from this index: the owned series
    /// values, both envelope halves, per-series `Vec` headers, the label
    /// vector, and the attached `LocMatrix` (nnz-based).  The grid sits
    /// behind an `Arc` and may be shared with a `GridRegistry` entry or
    /// other indexes — its bytes are reported here once per index, so
    /// summing `memory_bytes` across indexes can double-count shared
    /// grids (acceptable for a capacity-planning signal; the alternative
    /// silently under-reported SP-DTW indexes by the whole grid).
    pub fn memory_bytes(&self) -> usize {
        let vec_header = std::mem::size_of::<Vec<f64>>();
        let per_series = self.t * std::mem::size_of::<f64>() + vec_header;
        // values + upper + lower envelopes, each its own allocation
        let series_bytes = self.len() * per_series * 3;
        let label_bytes = self.labels.len() * std::mem::size_of::<usize>();
        let grid_bytes = self.loc.as_ref().map(|l| l.memory_bytes()).unwrap_or(0);
        series_bytes + label_bytes + grid_bytes
    }
}

/// Content hash of a raw `(t, labels, series)` payload — what
/// [`Index::content_hash`] would report for an index built (without
/// z-normalization) from the same train set, computable before paying
/// for the build.  The wire drift check hashes the submitted series
/// with this and compares against the registered index.
pub fn content_hash_of<'a>(
    t: usize,
    labels: &[usize],
    series: impl Iterator<Item = &'a [f64]>,
) -> u64 {
    use crate::search::persist::{fnv1a64_extend, FNV1A64_INIT};
    let mut h = fnv1a64_extend(FNV1A64_INIT, &(t as u64).to_le_bytes());
    h = fnv1a64_extend(h, &(labels.len() as u64).to_le_bytes());
    for &label in labels {
        h = fnv1a64_extend(h, &(label as u64).to_le_bytes());
    }
    for s in series {
        for &v in s {
            h = fnv1a64_extend(h, &v.to_bits().to_le_bytes());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;

    #[test]
    fn build_caches_envelopes_for_every_series() {
        let ds = synthetic::generate_scaled("CBF", 3, 12, 4).unwrap();
        let idx = Index::build(&ds.train, 5, 2);
        assert_eq!(idx.len(), ds.train.len());
        assert_eq!(idx.envs.len(), idx.len());
        assert_eq!(idx.t, ds.series_len());
        assert_eq!(idx.radius, 5);
        assert!(idx.lb_valid);
        for (i, (u, l)) in idx.envs.iter().enumerate() {
            for j in 0..idx.t {
                assert!(l[j] <= idx.series[i][j] && idx.series[i][j] <= u[j]);
            }
        }
    }

    #[test]
    fn unbounded_band_clamps_radius() {
        let train = from_pairs(vec![(0, vec![0.0, 1.0, 2.0]), (1, vec![2.0, 1.0, 0.0])]);
        let idx = Index::build(&train, usize::MAX, 1);
        assert_eq!(idx.radius, 2);
        assert_eq!(idx.band, usize::MAX);
        assert_eq!(idx.full_eval_cells(), 9);
    }

    #[test]
    fn spdtw_index_uses_grid_reach_and_weight_guard() {
        let train = from_pairs(vec![(0, vec![0.0; 6]), (1, vec![1.0; 6])]);
        let loc = LocMatrix::corridor(6, 2);
        let idx = Index::build_spdtw(&train, Arc::new(loc.clone()), 1);
        assert_eq!(idx.radius, 2);
        assert!(idx.lb_valid);
        assert_eq!(idx.full_eval_cells(), loc.nnz() as u64);

        // a grid with a sub-unit weight must disable the LB stages
        let soft = LocMatrix::from_triples(
            6,
            (0..6).map(|i| (i, i, if i == 3 { 0.5 } else { 1.0 })).collect(),
        );
        let idx2 = Index::build_spdtw(&train, Arc::new(soft), 1);
        assert!(!idx2.lb_valid);
    }

    #[test]
    fn memory_bytes_counts_grid_and_labels() {
        let train = from_pairs(vec![(0, vec![0.0; 16]), (1, vec![1.0; 16]), (2, vec![2.0; 16])]);
        let banded = Index::build(&train, 2, 1);
        let loc = Arc::new(LocMatrix::corridor(16, 2));
        let grid_bytes = loc.memory_bytes();
        let sp = Index::build_spdtw(&train, loc, 1);
        // same series payload; the SP index must additionally report the
        // grid footprint (the pre-fix report ignored it entirely).
        assert_eq!(sp.memory_bytes(), banded.memory_bytes() + grid_bytes);
        assert!(banded.memory_bytes() >= 3 * (16 * 8 * 3 + 8));
    }

    #[test]
    fn content_hash_tracks_payload_not_derived_state() {
        let train = from_pairs(vec![(0, vec![0.0, 1.0, 2.0]), (1, vec![2.0, 1.0, 0.0])]);
        // different radii (different envelopes), same payload → same hash
        let a = Index::build(&train, 1, 1);
        let b = Index::build(&train, 2, 1);
        assert_eq!(a.content_hash(), b.content_hash());
        // the standalone hash over the raw payload agrees
        let h = content_hash_of(3, &a.labels, a.series.iter().map(Vec::as_slice));
        assert_eq!(h, a.content_hash());
        // any value or label change moves the hash
        let tweaked = from_pairs(vec![(0, vec![0.0, 1.0, 2.5]), (1, vec![2.0, 1.0, 0.0])]);
        assert_ne!(Index::build(&tweaked, 1, 1).content_hash(), a.content_hash());
        let relabeled = from_pairs(vec![(3, vec![0.0, 1.0, 2.0]), (1, vec![2.0, 1.0, 0.0])]);
        assert_ne!(Index::build(&relabeled, 1, 1).content_hash(), a.content_hash());
    }

    #[test]
    fn build_from_spec_covers_the_searchable_family() {
        use crate::measures::spec::{GridSpec, InlineGrids, TrainGridResolver};
        let ds = synthetic::generate_scaled("CBF", 3, 10, 4).unwrap();
        let t = ds.series_len();
        let r = InlineGrids;

        // banded: identical to the direct builders
        let a = Index::build_from_spec(
            &ds.train,
            &MeasureSpec::BandedDtw { band_cells: 4 },
            false,
            &r,
            2,
        )
        .unwrap();
        assert_eq!(a.band, 4);
        assert_eq!(a.radius, Index::build(&ds.train, 4, 2).radius);

        let unb = Index::build_from_spec(&ds.train, &MeasureSpec::Dtw, false, &r, 2).unwrap();
        assert_eq!(unb.band, usize::MAX);

        let sc = Index::build_from_spec(
            &ds.train,
            &MeasureSpec::SakoeChiba { band_pct: 10.0 },
            false,
            &r,
            2,
        )
        .unwrap();
        assert_eq!(sc.band, crate::measures::sakoe_chiba::SakoeChibaDtw::new(10.0).band_for(t));

        let zn = Index::build_from_spec(
            &ds.train,
            &MeasureSpec::BandedDtw { band_cells: 3 },
            true,
            &r,
            2,
        )
        .unwrap();
        assert!(zn.znormalized);

        // spdtw via an inline corridor and via a learned grid
        let sp = Index::build_from_spec(
            &ds.train,
            &MeasureSpec::SpDtw { grid: GridSpec::Corridor { t, band: 2 } },
            false,
            &r,
            2,
        )
        .unwrap();
        assert!(sp.loc.is_some());
        assert_eq!(sp.radius, 2);
        let tr = TrainGridResolver { train: Some(&ds.train), grid: None, threads: 2 };
        let learned = Index::build_from_spec(
            &ds.train,
            &MeasureSpec::SpDtw { grid: GridSpec::Learned { theta: 0.5, gamma: 1.0 } },
            false,
            &tr,
            2,
        )
        .unwrap();
        assert_eq!(learned.loc.as_ref().unwrap().t, t);

        // typed rejections: non-searchable measure, znorm+spdtw,
        // grid length mismatch
        assert!(Index::build_from_spec(&ds.train, &MeasureSpec::Euclidean, false, &r, 2).is_err());
        assert!(Index::build_from_spec(
            &ds.train,
            &MeasureSpec::Krdtw { nu: 1.0, band_cells: None },
            false,
            &r,
            2
        )
        .is_err());
        assert!(Index::build_from_spec(
            &ds.train,
            &MeasureSpec::SpDtw { grid: GridSpec::Corridor { t, band: 2 } },
            true,
            &r,
            2
        )
        .is_err());
        assert!(Index::build_from_spec(
            &ds.train,
            &MeasureSpec::SpDtw { grid: GridSpec::Corridor { t: t + 1, band: 2 } },
            false,
            &r,
            2
        )
        .is_err());
    }

    #[test]
    fn znormalized_index_stores_unit_variance_series() {
        let train = from_pairs(vec![(0, vec![10.0, 20.0, 30.0, 40.0])]);
        let idx = Index::build_znormalized(&train, 1, 1);
        let s = &idx.series[0];
        let mean: f64 = s.iter().sum::<f64>() / s.len() as f64;
        assert!(mean.abs() < 1e-12);
        assert!(idx.znormalized);
    }
}
