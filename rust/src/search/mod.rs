//! Similarity-search engine: cascaded lower bounds + early-abandoning
//! DP over a prebuilt train-set index.
//!
//! The paper's LOC sparse grid cuts the DP cells *per comparison*; this
//! subsystem additionally cuts the *number of full comparisons per
//! query* — the indexing-family speed-up the paper surveys in §II-B.2 —
//! and composes with the sparse grid: the early-abandoning SP-DTW
//! threads the best-so-far upper bound through the LOC rows.
//!
//! ## The cascade
//!
//! For a k-NN query, every train candidate passes through a cascade of
//! increasingly expensive filters; the full DP runs only on survivors,
//! and even then it abandons as soon as a DP row proves the best-so-far
//! (the current k-th nearest distance) unbeatable:
//!
//! | stage | cost | filter |
//! |-------|------|--------|
//! | 1. `LB_Kim` | O(1) | envelope-clamped endpoint bound (see below) |
//! | 2. `LB_Keogh` | O(T) | query vs cached candidate envelope |
//! | 3. reversed `LB_Keogh` | O(T) | candidate vs query envelope |
//! | 4. early-abandoning DP | ≤ O(T·band) / O(nnz) | banded DTW or SP-DTW |
//!
//! The `LB_Kim` variant used here is the two *endpoint terms of
//! `LB_Keogh`* (clamped against the cached envelope), not the classic
//! raw-endpoint bound: that choice makes the chain *monotone* —
//! `LB_Kim ≤ LB_Keogh ≤ DP distance` always holds (property-tested in
//! `tests/prop_invariants.rs`), so a candidate pruned by a cheap stage
//! can never survive a later one.
//!
//! ## Exactness
//!
//! Pruning and abandoning are *admissible*: results are identical to
//! brute-force k-NN over the same DP measure.  Candidates are compared
//! by `(distance, train index)` lexicographically — the same total
//! order a stable sort over brute-force distances produces — and the
//! prune test [`engine`] uses is exact under that order.  The
//! early-abandoning kernels mirror the FP operation order of
//! [`crate::measures::dtw::dtw_banded`] / `SpDtw::eval`, so
//! non-abandoned values are bit-identical to the exhaustive ones.
//! Exactness covers degenerate grids too: candidates tying at the
//! unreachable-corner sentinel resolve by the same `(dist, train
//! index)` rule ([`early`] never abandons on more than it can prove),
//! so there is no exotic-grid caveat left.
//!
//! ## Layout
//!
//! | module | role |
//! |--------|------|
//! | [`lower_bounds`] | LB_Kim + reversed LB_Keogh over cached envelopes |
//! | [`early`] | early-abandoning banded DTW and SP-DTW kernels (scalar) |
//! | [`lanes`] | lane-batched EA kernels: 4–8 candidates per DP row in lockstep |
//! | [`index`] | [`Index`]: envelopes + normalized series cached per train set |
//! | [`engine`] | [`SearchEngine`]: k-NN queries, batch API, classification |
//! | [`persist`] | versioned on-disk index store (warm-start serving restarts) |
//!
//! Per-query [`PruneStats`] counters feed the paper's visited-cells
//! accounting (Table VI) and the coordinator's metrics export.

pub mod early;
pub mod engine;
pub mod index;
pub mod lanes;
pub mod lower_bounds;
pub mod persist;

pub use engine::{Neighbor, QueryResult, SearchEngine};
pub use index::Index;
pub use persist::{load_index, save_index, IndexFileInfo};

/// Which cascade stages are enabled.  All stages are admissible, so any
/// subset yields exact k-NN results — disabling stages only changes how
/// much work is pruned (the ablation axis of `bench_search`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cascade {
    /// O(1) envelope-endpoint bound (stage 1).
    pub kim: bool,
    /// O(T) query-vs-candidate-envelope bound (stage 2).
    pub keogh: bool,
    /// O(T) candidate-vs-query-envelope bound (stage 3).
    pub keogh_rev: bool,
    /// Row-wise early abandoning inside the full DP (stage 4).
    pub early_abandon: bool,
    /// Visit candidates in ascending LB_Kim order (tightens the
    /// best-so-far bound early, maximizing downstream pruning).
    pub order_by_lb: bool,
}

impl Default for Cascade {
    fn default() -> Self {
        Cascade {
            kim: true,
            keogh: true,
            keogh_rev: true,
            early_abandon: true,
            order_by_lb: true,
        }
    }
}

impl Cascade {
    /// Everything off: the engine degenerates to brute-force scanning
    /// (the bench baseline).
    pub fn none() -> Self {
        Cascade {
            kim: false,
            keogh: false,
            keogh_rev: false,
            early_abandon: false,
            order_by_lb: false,
        }
    }

    /// Cascade actually applied against `index`: lower-bound stages are
    /// dropped when the index cannot guarantee their admissibility
    /// (an SP-DTW grid with cell weights < 1 — see [`Index::lb_valid`]).
    pub fn effective(&self, index: &Index) -> Cascade {
        if index.lb_valid {
            *self
        } else {
            Cascade {
                kim: false,
                keogh: false,
                keogh_rev: false,
                order_by_lb: false,
                ..*self
            }
        }
    }
}

/// Per-query (mergeable) cascade counters — how each candidate left the
/// pipeline, plus the cell accounting behind the paper's Table VI.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Queries aggregated into this record.
    pub queries: u64,
    /// Candidates entering the cascade (= queries × train size).
    pub candidates: u64,
    /// Dropped by the O(1) LB_Kim stage.
    pub kim_pruned: u64,
    /// Dropped by LB_Keogh.
    pub keogh_pruned: u64,
    /// Dropped by the reversed LB_Keogh.
    pub rev_pruned: u64,
    /// Full DPs started but abandoned mid-way.
    pub abandoned: u64,
    /// Full DPs evaluated to completion.
    pub full_evals: u64,
    /// DP cells actually computed (including partial, abandoned DPs).
    pub dp_cells: u64,
    /// Cells scanned by O(T) lower-bound passes.
    pub lb_cells: u64,
}

impl PruneStats {
    pub fn merge(&mut self, o: &PruneStats) {
        self.queries += o.queries;
        self.candidates += o.candidates;
        self.kim_pruned += o.kim_pruned;
        self.keogh_pruned += o.keogh_pruned;
        self.rev_pruned += o.rev_pruned;
        self.abandoned += o.abandoned;
        self.full_evals += o.full_evals;
        self.dp_cells += o.dp_cells;
        self.lb_cells += o.lb_cells;
    }

    /// Candidates that never reached a completed full DP.
    pub fn pruned(&self) -> u64 {
        self.kim_pruned + self.keogh_pruned + self.rev_pruned + self.abandoned
    }

    /// Fraction of candidates pruned before a completed full DP.
    pub fn prune_ratio(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.pruned() as f64 / self.candidates as f64
        }
    }

    /// Total cells touched (DP + lower-bound scans) — comparable to a
    /// brute-force scan's `visited_cells`.
    pub fn total_cells(&self) -> u64 {
        self.dp_cells + self.lb_cells
    }

    pub fn report(&self) -> String {
        format!(
            "queries: {}  candidates: {}\n\
             pruned: {} kim / {} keogh / {} rev-keogh, {} abandoned, {} full DPs ({:.1}% pruned)\n\
             cells: {} DP + {} LB = {}",
            self.queries,
            self.candidates,
            self.kim_pruned,
            self.keogh_pruned,
            self.rev_pruned,
            self.abandoned,
            self.full_evals,
            100.0 * self.prune_ratio(),
            self.dp_cells,
            self.lb_cells,
            self.total_cells(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_accumulates() {
        let mut a = PruneStats {
            queries: 1,
            candidates: 10,
            kim_pruned: 2,
            keogh_pruned: 3,
            rev_pruned: 1,
            abandoned: 1,
            full_evals: 3,
            dp_cells: 100,
            lb_cells: 40,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.queries, 2);
        assert_eq!(a.candidates, 20);
        assert_eq!(a.pruned(), 14);
        assert_eq!(a.full_evals, 6);
        assert_eq!(a.total_cells(), 280);
        assert!((a.prune_ratio() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_ratio_is_zero() {
        assert_eq!(PruneStats::default().prune_ratio(), 0.0);
    }

    #[test]
    fn report_mentions_all_stages() {
        let r = PruneStats::default().report();
        assert!(r.contains("kim") && r.contains("keogh") && r.contains("abandoned"));
    }

    #[test]
    fn cascade_default_all_on_none_all_off() {
        let d = Cascade::default();
        assert!(d.kim && d.keogh && d.keogh_rev && d.early_abandon && d.order_by_lb);
        let n = Cascade::none();
        assert!(!n.kim && !n.keogh && !n.keogh_rev && !n.early_abandon && !n.order_by_lb);
    }
}
