//! Lane-batched early-abandoning DP kernels: evaluate up to
//! [`MAX_LANES`] candidates in lockstep against one query.
//!
//! ## Layout
//!
//! Candidate series are transposed into a **candidate-major**
//! (entry-parallel) layout before the DP runs: column `j` of every lane
//! is packed contiguously (`lane_vals[j * L + l]` = candidate `l`'s
//! value at index `j`), and the rolling DP rows use the same lane-major
//! blocks (`row[j * L + l]`).  Each DP cell update then touches one
//! contiguous chunk of `L` f64s — a vertical operation the
//! autovectorizer lowers to `f64x4`/`f64x8` instructions because the
//! inner lane loop has a *const-generic* trip count (the public entry
//! points monomorphize over `L ∈ 1..=8` via a `match`).
//!
//! ## Bit-exactness contract
//!
//! The per-lane floating-point operation sequence is **identical** to
//! the scalar kernels in [`crate::search::early`]: lanes never mix
//! arithmetically, only spatially.  For every lane `l`,
//! `dtw_banded_ea_lanes_into(..)[l]` equals
//! `dtw_banded_ea_into(ws, x, ys[l], band, ubs[l])` bit-for-bit —
//! value via `f64::to_bits` *and* `visited` — and likewise for the
//! SP-DTW pair.  There is no `fast` reordering path; vectorization
//! comes purely from evaluating independent candidates side by side.
//! Enforced by `tests/prop_lanes.rs` across interleaved lengths, bands,
//! grids (incl. cornerless and empty-row degenerates) and lane counts.
//!
//! ## Abandon masks and refill
//!
//! Each lane carries its own upper bound; a lane retires (`value:
//! None`) at the first row whose per-lane row minimum proves its bound,
//! exactly where the scalar kernel would return.  Retired lanes keep
//! computing cells (harmless: `phi ≥ 0`, no subtraction, `BIG` fills —
//! values stay finite) but stop accruing `visited`; once every lane has
//! retired the whole group stops.  Refill is **group-granular**: the
//! engine accumulates the next `L` cascade survivors and flushes them
//! as one lockstep DP (see `search::engine`) — mid-DP refill would
//! break row lockstep for no measurable gain.
//!
//! The same candidate-major layout is what a PJRT/XLA or GPU backend
//! wants for batched kernels; [`pack_candidate_major`] is the
//! documented host-side marshaller for the `runtime` batch entry points
//! (`LbKeoghBatch` / `SpdtwBatch`).

use crate::measures::workspace::{self, DpWorkspace};
use crate::measures::{phi, BIG};
use crate::search::early::EaResult;
use crate::sparse::loc::NO_PRED;
use crate::sparse::LocMatrix;

/// Widest lane group the kernels monomorphize: one AVX-512 register of
/// f64s, two AVX2 registers.
pub const MAX_LANES: usize = 8;

/// Lane width the engine uses unless configured otherwise
/// ([`crate::search::SearchEngine::with_lanes`]).
pub const DEFAULT_LANES: usize = 8;

/// Transpose `ys` (lane-major slices) into the candidate-major layout:
/// `out[j * L + l] = ys[l][j]`.  All lanes must share one length; the
/// buffer is reset via [`workspace::reset`] so reuse never allocates
/// once warm.  This is also the host-side marshaller for the
/// `runtime` batch API's `(T, L)` row-major operands.
pub fn pack_candidate_major(ys: &[&[f64]], out: &mut Vec<f64>) {
    let lanes = ys.len();
    let t = if lanes == 0 { 0 } else { ys[0].len() };
    workspace::reset(out, t * lanes, 0.0);
    for (l, y) in ys.iter().enumerate() {
        assert_eq!(y.len(), t, "lane length mismatch: {} != {t}", y.len());
        for (j, &v) in y.iter().enumerate() {
            out[j * lanes + l] = v;
        }
    }
}

/// Lane-batched [`crate::search::early::dtw_banded_ea_into`]: evaluate
/// `ys.len()` candidates (1..=[`MAX_LANES`]) against `x` in lockstep,
/// each under its own upper bound.  `out[l]` is bit-identical — value
/// and `visited` — to the scalar kernel run on lane `l` alone.
pub fn dtw_banded_ea_lanes_into(
    ws: &mut DpWorkspace,
    x: &[f64],
    ys: &[&[f64]],
    band: usize,
    ubs: &[f64],
    out: &mut [EaResult],
) {
    let lanes = ys.len();
    assert!(
        (1..=MAX_LANES).contains(&lanes),
        "lane count {lanes} not in 1..={MAX_LANES}"
    );
    assert_eq!(ubs.len(), lanes, "ubs length mismatch");
    assert_eq!(out.len(), lanes, "out length mismatch");
    match lanes {
        1 => dtw_lanes_fixed::<1>(ws, x, ys, band, ubs, out),
        2 => dtw_lanes_fixed::<2>(ws, x, ys, band, ubs, out),
        3 => dtw_lanes_fixed::<3>(ws, x, ys, band, ubs, out),
        4 => dtw_lanes_fixed::<4>(ws, x, ys, band, ubs, out),
        5 => dtw_lanes_fixed::<5>(ws, x, ys, band, ubs, out),
        6 => dtw_lanes_fixed::<6>(ws, x, ys, band, ubs, out),
        7 => dtw_lanes_fixed::<7>(ws, x, ys, band, ubs, out),
        8 => dtw_lanes_fixed::<8>(ws, x, ys, band, ubs, out),
        _ => unreachable!(),
    }
}

fn dtw_lanes_fixed<const L: usize>(
    ws: &mut DpWorkspace,
    x: &[f64],
    ys: &[&[f64]],
    band: usize,
    ubs: &[f64],
    out: &mut [EaResult],
) {
    let tx = x.len();
    let ty = ys[0].len();
    assert!(tx > 0 && ty > 0, "empty series");
    let slope = ty as f64 / tx as f64;
    let unbounded = band == usize::MAX || band >= tx.max(ty);
    let DpWorkspace {
        lane_row_a,
        lane_row_b,
        lane_vals,
        ..
    } = ws;
    pack_candidate_major(ys, lane_vals);
    workspace::reset(lane_row_a, ty * L, BIG);
    workspace::reset(lane_row_b, ty * L, BIG);
    let (mut prev, mut cur) = (lane_row_a, lane_row_b);
    let mut live = [true; L];
    let mut n_live = L;
    let mut visited = [0u64; L];

    for (i, &xi) in x.iter().enumerate() {
        let center = (i as f64 * slope) as usize;
        let (lo, hi) = if unbounded {
            (0, ty - 1)
        } else {
            (center.saturating_sub(band), (center + band).min(ty - 1))
        };
        let row_cells = (hi - lo + 1) as u64;
        let mut row_min = [f64::INFINITY; L];
        if i == 0 {
            let mut acc = [0.0f64; L];
            for j in lo..=hi {
                let base = j * L;
                let yrow = &lane_vals[base..base + L];
                let crow = &mut cur[base..base + L];
                for l in 0..L {
                    let a = acc[l] + phi(xi, yrow[l]);
                    acc[l] = a;
                    crow[l] = a;
                    if a < row_min[l] {
                        row_min[l] = a;
                    }
                }
            }
        } else {
            let mut prev_jm1 = [BIG; L];
            if lo > 0 {
                prev_jm1.copy_from_slice(&prev[(lo - 1) * L..lo * L]);
            }
            let mut cur_jm1 = [BIG; L];
            for j in lo..=hi {
                let base = j * L;
                let yrow = &lane_vals[base..base + L];
                let prow = &prev[base..base + L];
                let crow = &mut cur[base..base + L];
                for l in 0..L {
                    let pj = prow[l];
                    let mut b = pj;
                    if prev_jm1[l] < b {
                        b = prev_jm1[l];
                    }
                    if cur_jm1[l] < b {
                        b = cur_jm1[l];
                    }
                    let v = phi(xi, yrow[l]) + b;
                    crow[l] = v;
                    cur_jm1[l] = v;
                    prev_jm1[l] = pj;
                    if v < row_min[l] {
                        row_min[l] = v;
                    }
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
        if !unbounded {
            for c in cur.iter_mut() {
                *c = BIG;
            }
        }
        for l in 0..L {
            if live[l] {
                visited[l] += row_cells;
                if ubs[l].is_finite() && row_min[l] >= ubs[l] {
                    live[l] = false;
                    n_live -= 1;
                }
            }
        }
        if n_live == 0 {
            break;
        }
    }
    let corner = (ty - 1) * L;
    for l in 0..L {
        out[l] = EaResult {
            value: if live[l] { Some(prev[corner + l]) } else { None },
            visited: visited[l],
        };
    }
}

/// Lane-batched [`crate::search::early::spdtw_ea_into`]: the
/// entry-parallel LOC DP with a lane-major value array
/// (`lane_entries[k * L + l]`).  Per-lane op order, degenerate-grid
/// sentinels and empty-row proofs are all identical to the scalar
/// kernel, so each lane's result is bit-exact.
pub fn spdtw_ea_lanes_into(
    ws: &mut DpWorkspace,
    loc: &LocMatrix,
    x: &[f64],
    ys: &[&[f64]],
    ubs: &[f64],
    out: &mut [EaResult],
) {
    let lanes = ys.len();
    assert!(
        (1..=MAX_LANES).contains(&lanes),
        "lane count {lanes} not in 1..={MAX_LANES}"
    );
    assert_eq!(ubs.len(), lanes, "ubs length mismatch");
    assert_eq!(out.len(), lanes, "out length mismatch");
    let t = loc.t;
    assert_eq!(x.len(), t, "series length {} != grid size {t}", x.len());
    for y in ys {
        assert_eq!(y.len(), t, "series length {} != grid size {t}", y.len());
    }
    match lanes {
        1 => spdtw_lanes_fixed::<1>(ws, loc, x, ys, ubs, out),
        2 => spdtw_lanes_fixed::<2>(ws, loc, x, ys, ubs, out),
        3 => spdtw_lanes_fixed::<3>(ws, loc, x, ys, ubs, out),
        4 => spdtw_lanes_fixed::<4>(ws, loc, x, ys, ubs, out),
        5 => spdtw_lanes_fixed::<5>(ws, loc, x, ys, ubs, out),
        6 => spdtw_lanes_fixed::<6>(ws, loc, x, ys, ubs, out),
        7 => spdtw_lanes_fixed::<7>(ws, loc, x, ys, ubs, out),
        8 => spdtw_lanes_fixed::<8>(ws, loc, x, ys, ubs, out),
        _ => unreachable!(),
    }
}

fn spdtw_lanes_fixed<const L: usize>(
    ws: &mut DpWorkspace,
    loc: &LocMatrix,
    x: &[f64],
    ys: &[&[f64]],
    ubs: &[f64],
    out: &mut [EaResult],
) {
    let t = loc.t;
    // Cornerless grid: the exact answer is the constant sentinel for
    // every lane, no DP needed — same up-front decision as the scalar
    // kernel, `visited` stays 0.
    let Some(corner_k) = loc.index_of(t - 1, t - 1) else {
        for r in out.iter_mut() {
            *r = EaResult {
                value: Some(BIG + BIG),
                visited: 0,
            };
        }
        return;
    };
    let n = loc.nnz();
    let DpWorkspace {
        lane_entries,
        lane_vals,
        ..
    } = ws;
    pack_candidate_major(ys, lane_vals);
    workspace::reset(lane_entries, n * L, BIG);
    let mut live = [true; L];
    let mut n_live = L;
    let mut visited = [0u64; L];

    for r in 0..t {
        let (rs, re) = (loc.row_ptr[r], loc.row_ptr[r + 1]);
        let mut row_min = [f64::INFINITY; L];
        let xr = x[r];
        for k in rs..re {
            let c = loc.cols[k] as usize;
            let w = loc.weights[k];
            let p = loc.preds[k];
            let origin = r == 0 && c == 0;
            let ybase = c * L;
            let dbase = k * L;
            for l in 0..L {
                let local = w * phi(xr, lane_vals[ybase + l]);
                let best = if origin {
                    0.0
                } else {
                    let mut b = BIG;
                    for &pi in &p {
                        if pi != NO_PRED {
                            let v = lane_entries[pi as usize * L + l];
                            if v < b {
                                b = v;
                            }
                        }
                    }
                    b
                };
                let v = local + best;
                lane_entries[dbase + l] = v;
                if v < row_min[l] {
                    row_min[l] = v;
                }
            }
        }
        let row_cells = (re - rs) as u64;
        for l in 0..L {
            if live[l] {
                visited[l] += row_cells;
                // Same proven-bound rule as the scalar kernel: an empty
                // row only proves ≥ BIG (see `early::spdtw_ea_into`).
                let proven = if re == rs { BIG } else { row_min[l] };
                if ubs[l].is_finite() && proven >= ubs[l] {
                    live[l] = false;
                    n_live -= 1;
                }
            }
        }
        if n_live == 0 {
            break;
        }
    }
    let corner = corner_k * L;
    for l in 0..L {
        out[l] = EaResult {
            value: if live[l] {
                Some(lane_entries[corner + l])
            } else {
                None
            },
            visited: visited[l],
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::early::{dtw_banded_ea_into, spdtw_ea_into};
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
        (0..t).map(|_| rng.normal()).collect()
    }

    fn blank() -> EaResult {
        EaResult {
            value: None,
            visited: 0,
        }
    }

    #[test]
    fn pack_transposes_candidate_major() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        let ys: Vec<&[f64]> = vec![&a, &b];
        let mut out = Vec::new();
        pack_candidate_major(&ys, &mut out);
        assert_eq!(out, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        // reuse resets, never appends
        pack_candidate_major(&ys[..1], &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn dtw_lanes_match_scalar_bitwise_per_lane() {
        let mut rng = Pcg64::new(41);
        let mut ws = DpWorkspace::new();
        let mut sws = DpWorkspace::new();
        for lanes in [1usize, 3, 4, 8] {
            let tx = 5 + rng.below(20);
            let ty = 5 + rng.below(20);
            let x = rand_vec(&mut rng, tx);
            let cands: Vec<Vec<f64>> = (0..lanes).map(|_| rand_vec(&mut rng, ty)).collect();
            let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
            for band in [2usize, usize::MAX] {
                let ubs: Vec<f64> = (0..lanes)
                    .map(|l| if l % 2 == 0 { f64::INFINITY } else { 0.5 + rng.normal().abs() })
                    .collect();
                let mut out = vec![blank(); lanes];
                dtw_banded_ea_lanes_into(&mut ws, &x, &ys, band, &ubs, &mut out);
                for l in 0..lanes {
                    let scalar = dtw_banded_ea_into(&mut sws, &x, ys[l], band, ubs[l]);
                    assert_eq!(out[l].visited, scalar.visited, "lanes={lanes} l={l} band={band}");
                    assert_eq!(
                        out[l].value.map(f64::to_bits),
                        scalar.value.map(f64::to_bits),
                        "lanes={lanes} l={l} band={band}"
                    );
                }
            }
        }
    }

    #[test]
    fn spdtw_lanes_match_scalar_bitwise_per_lane() {
        let mut rng = Pcg64::new(43);
        let mut ws = DpWorkspace::new();
        let mut sws = DpWorkspace::new();
        let t = 14;
        let loc = LocMatrix::corridor(t, 3);
        for lanes in [1usize, 4, 7, 8] {
            let x = rand_vec(&mut rng, t);
            let cands: Vec<Vec<f64>> = (0..lanes).map(|_| rand_vec(&mut rng, t)).collect();
            let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
            let ubs: Vec<f64> = (0..lanes)
                .map(|l| if l % 3 == 0 { f64::INFINITY } else { rng.normal().abs() })
                .collect();
            let mut out = vec![blank(); lanes];
            spdtw_ea_lanes_into(&mut ws, &loc, &x, &ys, &ubs, &mut out);
            for l in 0..lanes {
                let scalar = spdtw_ea_into(&mut sws, &loc, &x, ys[l], ubs[l]);
                assert_eq!(out[l].visited, scalar.visited, "lanes={lanes} l={l}");
                assert_eq!(
                    out[l].value.map(f64::to_bits),
                    scalar.value.map(f64::to_bits),
                    "lanes={lanes} l={l}"
                );
            }
        }
    }

    #[test]
    fn all_lanes_abandoning_stops_the_group() {
        // every lane gets ub=0 → retire on row 0, visited = first row only
        let mut ws = DpWorkspace::new();
        let x = vec![1.0; 12];
        let y = vec![2.0; 12];
        let ys: Vec<&[f64]> = vec![&y, &y, &y, &y];
        let ubs = [0.0; 4];
        let mut out = [blank(); 4];
        dtw_banded_ea_lanes_into(&mut ws, &x, &ys, usize::MAX, &ubs, &mut out);
        for r in &out {
            assert_eq!(r.value, None);
            assert_eq!(r.visited, 12);
        }
    }

    #[test]
    fn cornerless_grid_fills_sentinel_for_every_lane() {
        let loc = LocMatrix::from_triples(4, vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]);
        let mut ws = DpWorkspace::new();
        let x = vec![0.5; 4];
        let y = vec![-0.5; 4];
        let ys: Vec<&[f64]> = vec![&y, &y, &y];
        let ubs = [1.0; 3];
        let mut out = [blank(); 3];
        spdtw_ea_lanes_into(&mut ws, &loc, &x, &ys, &ubs, &mut out);
        for r in &out {
            assert_eq!(r.value.map(f64::to_bits), Some((BIG + BIG).to_bits()));
            assert_eq!(r.visited, 0);
        }
    }
}
