//! The cascade k-NN [`SearchEngine`]: exact nearest-neighbor queries
//! that prune with lower bounds and abandon DPs early, plus the batch /
//! classification APIs parallelized over [`crate::pool::par_map`].
//!
//! ## Exactness contract
//!
//! Candidates are ranked by `(distance, train index)` lexicographically
//! (`f64::total_cmp` on the distance) — exactly the order a stable sort
//! over brute-force distances produces, so the returned neighbor list is
//! bit-identical to `classify::nn::classify_knn`'s top-k.  The prune
//! test accounts for boundary ties: a candidate whose lower bound
//! *equals* the current k-th distance is only skipped when its index
//! also loses the tie-break.

use std::cmp::Ordering;
use std::sync::Arc;

use crate::classify::nn::vote;
use crate::classify::EvalResult;
use crate::data::{znormalize_in_place, LabeledSet, TimeSeries};
use crate::measures::lb_keogh::envelope_into;
use crate::measures::workspace::{self, DpWorkspace};
use crate::pool;
use crate::search::early::EaResult;
use crate::search::lanes::{DEFAULT_LANES, MAX_LANES};
use crate::search::lower_bounds::{lb_keogh_sum, lb_kim};
use crate::search::{Cascade, Index, PruneStats};
use crate::util::mathx::next_up_f64;

/// One retrieved neighbor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub dist: f64,
    pub label: usize,
    pub train_idx: usize,
}

/// Result of one k-NN query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// The k nearest train series, ascending by `(dist, train_idx)`.
    pub neighbors: Vec<Neighbor>,
    pub stats: PruneStats,
}

impl QueryResult {
    /// Majority-vote label over the neighbors (same tie-break as the
    /// brute-force k-NN path).
    pub fn predicted_label(&self) -> usize {
        let pairs: Vec<(f64, usize)> =
            self.neighbors.iter().map(|n| (n.dist, n.label)).collect();
        vote(&pairs)
    }
}

/// Cascade k-NN searcher over a shared [`Index`].
#[derive(Clone)]
pub struct SearchEngine {
    pub index: Arc<Index>,
    pub cascade: Cascade,
    /// DP lane width: cascade survivors are evaluated in lockstep
    /// groups of up to this many candidates per kernel call (1 =
    /// scalar per-candidate path).  Every width returns bit-identical
    /// neighbors — see [`crate::search::lanes`] and `flush_lane_group`.
    pub lanes: usize,
}

impl SearchEngine {
    pub fn new(index: Arc<Index>, cascade: Cascade) -> SearchEngine {
        SearchEngine {
            index,
            cascade,
            lanes: DEFAULT_LANES,
        }
    }

    /// [`Self::new`] with an explicit DP lane width, clamped to
    /// `1..=`[`MAX_LANES`].  The knob trades instruction-level
    /// parallelism against threshold tightness *within* one lane group;
    /// the returned neighbor lists are bit-identical for every width
    /// (property: `prop_lanes.rs` lane-count invariance).
    pub fn with_lanes(index: Arc<Index>, cascade: Cascade, lanes: usize) -> SearchEngine {
        SearchEngine {
            index,
            cascade,
            lanes: lanes.clamp(1, MAX_LANES),
        }
    }

    /// Build an index for any searchable [`MeasureSpec`] over `train`
    /// and wrap it in an engine — the spec-driven constructor every
    /// surface shares (see [`Index::build_from_spec`] for which specs
    /// are searchable and how grids resolve).
    pub fn from_spec(
        train: &crate::data::LabeledSet,
        spec: &crate::measures::spec::MeasureSpec,
        cascade: Cascade,
        znormalize: bool,
        grids: &dyn crate::measures::spec::GridResolver,
        threads: usize,
    ) -> crate::error::Result<SearchEngine> {
        Ok(SearchEngine::new(
            Arc::new(Index::build_from_spec(train, spec, znormalize, grids, threads)?),
            cascade,
        ))
    }

    /// k nearest neighbors of `query`.
    pub fn knn(&self, query: &TimeSeries, k: usize) -> QueryResult {
        self.knn_values(&query.values, k)
    }

    /// [`Self::knn`] against caller-provided scratch.
    pub fn knn_with(&self, ws: &mut DpWorkspace, query: &TimeSeries, k: usize) -> QueryResult {
        self.knn_values_with(ws, &query.values, k)
    }

    /// k nearest neighbors of a raw value slice (TLS workspace).
    pub fn knn_values(&self, query: &[f64], k: usize) -> QueryResult {
        workspace::with_tls(|ws| self.knn_values_with(ws, query, k))
    }

    /// k nearest neighbors of a raw value slice, with every per-query
    /// buffer (normalized query, query envelope, LB values, visit
    /// order, top-k list, DP rows) drawn from `ws`: the whole candidate
    /// loop runs with zero steady-state heap allocations, and returns
    /// results bit-identical to the allocating path.
    pub fn knn_values_with(&self, ws: &mut DpWorkspace, query: &[f64], k: usize) -> QueryResult {
        self.knn_values_env_opt(ws, query, k, None)
    }

    /// [`Self::knn_values_with`] with a caller-supplied query envelope:
    /// `(q_upper, q_lower)` must be the Lemire envelope, at the index
    /// radius, of the *prepared* query (the raw slice for a raw index —
    /// z-normalized indexes re-normalize per call, so their envelope
    /// cannot be precomputed and this entry point rejects them).  The
    /// streaming monitor maintains that envelope incrementally; results
    /// — neighbors *and* stats — are bit-identical to
    /// [`Self::knn_values_with`], which rebuilds it from scratch.
    pub fn knn_values_with_query_env(
        &self,
        ws: &mut DpWorkspace,
        query: &[f64],
        k: usize,
        q_upper: &[f64],
        q_lower: &[f64],
    ) -> QueryResult {
        assert!(
            !self.index.znormalized,
            "precomputed query envelopes require a non-z-normalized index"
        );
        assert_eq!(q_upper.len(), self.index.t, "upper envelope length");
        assert_eq!(q_lower.len(), self.index.t, "lower envelope length");
        self.knn_values_env_opt(ws, query, k, Some((q_upper, q_lower)))
    }

    fn knn_values_env_opt(
        &self,
        ws: &mut DpWorkspace,
        query: &[f64],
        k: usize,
        qenv: Option<(&[f64], &[f64])>,
    ) -> QueryResult {
        let idx = &*self.index;
        assert!(k >= 1, "k must be >= 1");
        assert_eq!(
            query.len(),
            idx.t,
            "query length {} != indexed length {}",
            query.len(),
            idx.t
        );
        // Per-query scratch is taken out of the workspace (and restored
        // before returning) so the DP stages below can still borrow
        // `ws` for their rolling rows / entry arrays.
        let mut qbuf = std::mem::take(&mut ws.query);
        let mut qu = std::mem::take(&mut ws.env_upper);
        let mut ql = std::mem::take(&mut ws.env_lower);
        let mut lbs = std::mem::take(&mut ws.lbs);
        let mut order = std::mem::take(&mut ws.order);
        let mut top = std::mem::take(&mut ws.top);

        let q: &[f64] = if idx.znormalized {
            qbuf.clear();
            qbuf.extend_from_slice(query);
            znormalize_in_place(&mut qbuf);
            &qbuf
        } else {
            query
        };

        let cas = self.cascade.effective(idx);
        let mut stats = PruneStats {
            queries: 1,
            ..Default::default()
        };

        // Query-side envelope (reversed LB_Keogh): built once per query,
        // or copied from a caller who maintained it incrementally.  The
        // accounting is identical either way, so streaming and batch
        // queries report bit-identical stats.
        let have_qenv = cas.keogh_rev;
        if have_qenv {
            stats.lb_cells += idx.t as u64;
            match qenv {
                Some((u, l)) => {
                    qu.clear();
                    qu.extend_from_slice(u);
                    ql.clear();
                    ql.extend_from_slice(l);
                }
                None => {
                    envelope_into(q, idx.radius, &mut qu, &mut ql, &mut ws.maxq, &mut ws.minq)
                }
            }
        }

        // O(1)-per-candidate LB_Kim values, also reused as the visit
        // order (ascending bound tightens best-so-far early).
        let n = idx.len();
        let have_kim = cas.kim || cas.order_by_lb;
        if have_kim {
            lbs.clear();
            lbs.extend((0..n).map(|j| {
                let (u, l) = &idx.envs[j];
                lb_kim(q, u, l)
            }));
        }
        order.clear();
        order.extend(0..n);
        if cas.order_by_lb {
            // Unstable sort is exact here: `(lb, index)` is a total
            // order with no duplicate keys, so the permutation is
            // unique — and it does not allocate a merge buffer.
            order.sort_unstable_by(|&a, &b| lbs[a].total_cmp(&lbs[b]).then(a.cmp(&b)));
        }

        // Current best k as (dist, train_idx), ascending lexicographic.
        top.clear();
        top.reserve(k + 1);
        // Cascade survivors are evaluated in lane groups of up to
        // `lanes` candidates flushed as one lockstep DP; `lanes == 1`
        // is the scalar per-candidate path.  Both paths return
        // bit-identical neighbors (see `flush_lane_group`); only the
        // work accounting (which candidates abandon vs complete) may
        // differ between widths.
        let lanes = self.lanes.clamp(1, MAX_LANES);
        let mut group = [0usize; MAX_LANES];
        let mut glen = 0usize;
        for &j in &order {
            stats.candidates += 1;
            if cas.kim && cannot_beat(lbs[j], j, &top, k) {
                stats.kim_pruned += 1;
                continue;
            }
            if cas.keogh {
                let (u, l) = &idx.envs[j];
                let lb = lb_keogh_sum(q, u, l);
                stats.lb_cells += idx.t as u64;
                if cannot_beat(lb, j, &top, k) {
                    stats.keogh_pruned += 1;
                    continue;
                }
            }
            if have_qenv {
                let lb = lb_keogh_sum(&idx.series[j], &qu, &ql);
                stats.lb_cells += idx.t as u64;
                if cannot_beat(lb, j, &top, k) {
                    stats.rev_pruned += 1;
                    continue;
                }
            }
            if lanes == 1 {
                let ub = abandon_threshold(j, &top, k, cas.early_abandon);
                let ea = idx.full_eval_with(ws, q, j, ub);
                stats.dp_cells += ea.visited;
                match ea.value {
                    None => stats.abandoned += 1,
                    Some(v) => {
                        stats.full_evals += 1;
                        insert_neighbor(&mut top, k, v, j);
                    }
                }
            } else {
                group[glen] = j;
                glen += 1;
                if glen == lanes {
                    flush_lane_group(
                        idx,
                        ws,
                        q,
                        &group[..glen],
                        k,
                        cas.early_abandon,
                        &mut top,
                        &mut stats,
                    );
                    glen = 0;
                }
            }
        }
        // Ragged tail (survivors % lanes != 0): the partial group
        // flushes through the matching narrower monomorphization.
        if glen > 0 {
            flush_lane_group(
                idx,
                ws,
                q,
                &group[..glen],
                k,
                cas.early_abandon,
                &mut top,
                &mut stats,
            );
        }
        let neighbors = top
            .drain(..)
            .map(|(dist, j)| Neighbor {
                dist,
                label: idx.labels[j],
                train_idx: j,
            })
            .collect();
        ws.query = qbuf;
        ws.env_upper = qu;
        ws.env_lower = ql;
        ws.lbs = lbs;
        ws.order = order;
        ws.top = top;
        QueryResult { neighbors, stats }
    }

    /// Exact k-NN over a candidate *subset*: the same cascade (LB
    /// prunes, early-abandoning DP, `(dist, train_idx)` order) scanned
    /// over `candidates` only, in the given order.  Callers pass
    /// distinct indices (debug-asserted), typically ascending by an
    /// approximate ranking so thresholds tighten early — correctness is
    /// scan-order-independent, only the work accounting shifts.  This
    /// is the RWS pre-filter's refine stage; over the full candidate
    /// set `0..n` in order it is bit-identical (neighbors and stats) to
    /// [`Self::knn_values_with`] with `order_by_lb` off and `lanes ==
    /// 1` (this path evaluates survivors scalar, one DP per candidate).
    pub fn knn_among_with(
        &self,
        ws: &mut DpWorkspace,
        query: &[f64],
        k: usize,
        candidates: &[usize],
    ) -> QueryResult {
        let idx = &*self.index;
        assert!(k >= 1, "k must be >= 1");
        assert_eq!(
            query.len(),
            idx.t,
            "query length {} != indexed length {}",
            query.len(),
            idx.t
        );
        let mut qbuf = std::mem::take(&mut ws.query);
        let mut qu = std::mem::take(&mut ws.env_upper);
        let mut ql = std::mem::take(&mut ws.env_lower);
        let mut top = std::mem::take(&mut ws.top);

        let q: &[f64] = if idx.znormalized {
            qbuf.clear();
            qbuf.extend_from_slice(query);
            znormalize_in_place(&mut qbuf);
            &qbuf
        } else {
            query
        };

        let cas = self.cascade.effective(idx);
        let mut stats = PruneStats {
            queries: 1,
            ..Default::default()
        };
        let have_qenv = cas.keogh_rev;
        if have_qenv {
            stats.lb_cells += idx.t as u64;
            envelope_into(q, idx.radius, &mut qu, &mut ql, &mut ws.maxq, &mut ws.minq);
        }
        top.clear();
        top.reserve(k + 1);
        for (ci, &j) in candidates.iter().enumerate() {
            debug_assert!(j < idx.len(), "candidate {j} out of range");
            debug_assert!(
                !candidates[..ci].contains(&j),
                "candidates must be distinct"
            );
            stats.candidates += 1;
            if cas.kim {
                let (u, l) = &idx.envs[j];
                let lb = lb_kim(q, u, l);
                if cannot_beat(lb, j, &top, k) {
                    stats.kim_pruned += 1;
                    continue;
                }
            }
            if cas.keogh {
                let (u, l) = &idx.envs[j];
                let lb = lb_keogh_sum(q, u, l);
                stats.lb_cells += idx.t as u64;
                if cannot_beat(lb, j, &top, k) {
                    stats.keogh_pruned += 1;
                    continue;
                }
            }
            if have_qenv {
                let lb = lb_keogh_sum(&idx.series[j], &qu, &ql);
                stats.lb_cells += idx.t as u64;
                if cannot_beat(lb, j, &top, k) {
                    stats.rev_pruned += 1;
                    continue;
                }
            }
            let ub = abandon_threshold(j, &top, k, cas.early_abandon);
            let ea = idx.full_eval_with(ws, q, j, ub);
            stats.dp_cells += ea.visited;
            match ea.value {
                None => stats.abandoned += 1,
                Some(v) => {
                    stats.full_evals += 1;
                    insert_neighbor(&mut top, k, v, j);
                }
            }
        }
        let neighbors = top
            .drain(..)
            .map(|(dist, j)| Neighbor {
                dist,
                label: idx.labels[j],
                train_idx: j,
            })
            .collect();
        ws.query = qbuf;
        ws.env_upper = qu;
        ws.env_lower = ql;
        ws.top = top;
        QueryResult { neighbors, stats }
    }

    /// Batch k-NN over a whole query set: parallel across queries on
    /// the persistent pool, one long-lived workspace per worker.  Each
    /// call is one scheduler epoch, so batches submitted by distinct
    /// threads (the coordinator's concurrent clients) overlap instead
    /// of serializing.  Work is distributed size-aware — spans weighted
    /// by query length, so mixed-cost items cannot make one worker the
    /// critical path (uniform-length sets degrade to plain chunking).
    pub fn batch_knn(&self, queries: &LabeledSet, k: usize, threads: usize) -> Vec<QueryResult> {
        let weights: Vec<usize> = queries.series.iter().map(|s| s.values.len()).collect();
        pool::par_map_ws_weighted(queries.len(), threads, &weights, |i, ws| {
            self.knn_with(ws, &queries.series[i], k)
        })
    }

    /// [`Self::batch_knn`] over raw value slices — the coordinator's
    /// `submit_batch_search` path, which carries queries as plain
    /// vectors off the wire.
    pub fn batch_knn_values(
        &self,
        queries: &[Vec<f64>],
        k: usize,
        threads: usize,
    ) -> Vec<QueryResult> {
        let weights: Vec<usize> = queries.iter().map(Vec::len).collect();
        pool::par_map_ws_weighted(queries.len(), threads, &weights, |i, ws| {
            self.knn_values_with(ws, &queries[i], k)
        })
    }

    /// k-NN classification of `test`, with aggregate prune counters.
    /// `EvalResult::visited_cells` counts every cell touched (DP + LB
    /// scans) so it stays comparable to the brute-force path;
    /// `comparisons` counts candidates that entered the cascade.
    pub fn classify(
        &self,
        test: &LabeledSet,
        k: usize,
        threads: usize,
    ) -> (EvalResult, PruneStats) {
        let results = self.batch_knn(test, k, threads);
        let mut stats = PruneStats::default();
        let pred: Vec<usize> = results
            .iter()
            .map(|r| {
                stats.merge(&r.stats);
                r.predicted_label()
            })
            .collect();
        let eval =
            EvalResult::from_predictions(test, &pred, stats.total_cells(), stats.candidates);
        (eval, stats)
    }
}

/// Flush one lane group: evaluate `group` (1..=[`MAX_LANES`] cascade
/// survivors) against `q` in lockstep, then fold the per-lane results
/// into the top-k in group order.
///
/// Exactness: each lane's abandon threshold is frozen when the group
/// flushes, *before* any group member inserts — never tighter than the
/// sequential path's threshold for the same candidate, because the
/// k-th best only tightens as inserts happen.  So the lane engine
/// completes a superset of the candidates the scalar schedule
/// completes; completed values are bit-exact scalar DP values; and an
/// abandoned candidate provably cannot enter the final top-k under the
/// `(dist, idx)` order (the threshold came from k already-better
/// entries).  The final top-k is therefore bit-identical for every
/// lane width — only `PruneStats`' abandoned/full_evals split and
/// `dp_cells` may differ between widths.
fn flush_lane_group(
    idx: &Index,
    ws: &mut DpWorkspace,
    q: &[f64],
    group: &[usize],
    k: usize,
    early_abandon: bool,
    top: &mut Vec<(f64, usize)>,
    stats: &mut PruneStats,
) {
    let g = group.len();
    let mut ubs = [f64::INFINITY; MAX_LANES];
    for (u, &j) in ubs.iter_mut().zip(group) {
        *u = abandon_threshold(j, top, k, early_abandon);
    }
    let mut res = [EaResult {
        value: None,
        visited: 0,
    }; MAX_LANES];
    idx.full_eval_lanes_with(ws, q, group, &ubs[..g], &mut res[..g]);
    for (&j, r) in group.iter().zip(res.iter()) {
        stats.dp_cells += r.visited;
        match r.value {
            None => stats.abandoned += 1,
            Some(v) => {
                stats.full_evals += 1;
                insert_neighbor(top, k, v, j);
            }
        }
    }
}

/// Exact prune test under the `(dist, idx)` lexicographic order: true
/// iff a candidate with true distance ≥ `lb` can no longer enter the
/// current top-k.
fn cannot_beat(lb: f64, j: usize, top: &[(f64, usize)], k: usize) -> bool {
    if top.len() < k {
        return false;
    }
    let (wd, wj) = top[k - 1];
    match lb.total_cmp(&wd) {
        // dist >= lb > worst: can never displace it.
        Ordering::Greater => true,
        // dist >= lb == worst: displaces only on an exact distance tie
        // won by a smaller train index.
        Ordering::Equal => j > wj,
        Ordering::Less => false,
    }
}

/// Abandon threshold for the DP stage: the loosest bound that still
/// guarantees an abandoned candidate could not have entered the top-k
/// (ties included).  INFINITY when the top-k is not yet full or early
/// abandoning is disabled.
fn abandon_threshold(j: usize, top: &[(f64, usize)], k: usize, enabled: bool) -> f64 {
    if !enabled || top.len() < k {
        return f64::INFINITY;
    }
    let (wd, wj) = top[k - 1];
    if j > wj {
        // a tie at wd loses to wj anyway: abandoning at >= wd is safe
        wd
    } else {
        // j would win a tie at wd, so only abandon strictly above it
        next_up_f64(wd)
    }
}

/// Insert `(d, j)` into the ascending `(dist, idx)` top-k list.
fn insert_neighbor(top: &mut Vec<(f64, usize)>, k: usize, d: f64, j: usize) {
    let pos = top.partition_point(|&(bd, bj)| match bd.total_cmp(&d) {
        Ordering::Less => true,
        Ordering::Equal => bj < j,
        Ordering::Greater => false,
    });
    if pos >= k {
        return;
    }
    top.insert(pos, (d, j));
    top.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::splits::from_pairs;
    use crate::data::synthetic;
    use crate::measures::dtw::dtw_banded;
    use crate::sparse::LocMatrix;
    use crate::util::rng::Pcg64;

    /// Brute-force top-k under the same (dist, idx) order.
    fn brute_topk(
        idx: &Index,
        query: &[f64],
        k: usize,
    ) -> Vec<(f64, usize)> {
        let mut all: Vec<(f64, usize)> = (0..idx.len())
            .map(|j| {
                let d = match &idx.loc {
                    Some(loc) => crate::measures::spdtw::SpDtw::from_arc(Arc::clone(loc))
                        .eval(query, &idx.series[j])
                        .value,
                    None => dtw_banded(query, &idx.series[j], idx.band).value,
                };
                (d, j)
            })
            .collect();
        all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        all.truncate(k);
        all
    }

    #[test]
    fn knn_matches_brute_force_bitwise() {
        let ds = synthetic::generate_scaled("CBF", 21, 20, 10).unwrap();
        let band = ds.series_len() / 10;
        let idx = Arc::new(Index::build(&ds.train, band, 2));
        for cascade in [Cascade::default(), Cascade::none()] {
            let eng = SearchEngine::new(Arc::clone(&idx), cascade);
            for probe in &ds.test.series {
                for k in [1usize, 3] {
                    let got = eng.knn(probe, k);
                    let want = brute_topk(&idx, &probe.values, k);
                    assert_eq!(got.neighbors.len(), want.len());
                    for (n, (wd, wj)) in got.neighbors.iter().zip(&want) {
                        assert_eq!(n.dist.to_bits(), wd.to_bits());
                        assert_eq!(n.train_idx, *wj);
                    }
                }
            }
        }
    }

    #[test]
    fn spdtw_engine_matches_brute_force() {
        let ds = synthetic::generate_scaled("Gun-Point", 9, 16, 8).unwrap();
        let loc = Arc::new(LocMatrix::corridor(ds.series_len(), 4));
        let idx = Arc::new(Index::build_spdtw(&ds.train, loc, 2));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        for probe in &ds.test.series {
            let got = eng.knn(probe, 1);
            let want = brute_topk(&idx, &probe.values, 1);
            assert_eq!(got.neighbors[0].dist.to_bits(), want[0].0.to_bits());
            assert_eq!(got.neighbors[0].train_idx, want[0].1);
        }
    }

    #[test]
    fn cascade_prunes_and_saves_cells() {
        let ds = synthetic::generate_scaled("CBF", 4, 30, 20).unwrap();
        let band = (ds.series_len() as f64 * 0.1) as usize;
        let idx = Arc::new(Index::build(&ds.train, band, 2));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let (_, stats) = eng.classify(&ds.test, 1, 2);
        assert!(stats.pruned() > 0, "cascade pruned nothing");
        let brute_cells = idx.full_eval_cells() * stats.candidates;
        assert!(
            stats.dp_cells < brute_cells,
            "no DP cells saved: {} vs {}",
            stats.dp_cells,
            brute_cells
        );
        assert_eq!(
            stats.candidates,
            (ds.test.len() * ds.train.len()) as u64
        );
        assert_eq!(
            stats.kim_pruned
                + stats.keogh_pruned
                + stats.rev_pruned
                + stats.abandoned
                + stats.full_evals,
            stats.candidates
        );
    }

    #[test]
    fn duplicate_train_series_tie_break_matches_brute() {
        // identical candidates produce exact distance ties: the engine
        // must keep the smaller train index, like a stable sort.
        let train = from_pairs(vec![
            (7, vec![0.0, 1.0, 0.0, -1.0]),
            (3, vec![0.0, 1.0, 0.0, -1.0]),
            (1, vec![5.0, 5.0, 5.0, 5.0]),
        ]);
        let idx = Arc::new(Index::build(&train, 1, 1));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let r = eng.knn_values(&[0.0, 1.0, 0.0, -1.0], 2);
        assert_eq!(r.neighbors[0].train_idx, 0);
        assert_eq!(r.neighbors[0].label, 7);
        assert_eq!(r.neighbors[1].train_idx, 1);
        assert_eq!(r.neighbors[1].dist, 0.0);
    }

    #[test]
    fn sentinel_tie_at_kth_boundary_matches_brute() {
        // Disconnected grid (row 2 empty, corner present): every
        // candidate's distance is `local(3,3) + BIG`, which depends only
        // on the candidate's last value — so train 0 and 1 tie exactly.
        // The LB visit order puts train 1 first (its envelope hugs the
        // query), so train 0 meets the boundary as the tie-WINNER
        // (smaller index): the pre-fix empty-row abandon dropped it.
        let loc = Arc::new(LocMatrix::from_triples(
            4,
            vec![(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (3, 3, 1.0)],
        ));
        let train = from_pairs(vec![
            (0, vec![10.0, 10.0, 0.0, 5.0]),
            (1, vec![-3.0, -3.0, 0.0, 5.0]),
        ]);
        let idx = Arc::new(Index::build_spdtw(&train, loc, 1));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let q = [-3.0, 0.0, 0.0, 0.0];
        let got = eng.knn_values(&q, 1);
        let want = brute_topk(&idx, &q, 1);
        assert_eq!(got.neighbors.len(), 1);
        assert_eq!(got.neighbors[0].dist.to_bits(), want[0].0.to_bits());
        assert_eq!(got.neighbors[0].train_idx, want[0].1);
        assert_eq!(got.neighbors[0].train_idx, 0, "tie must go to the smaller index");
    }

    #[test]
    fn classification_agrees_with_bruteforce_knn() {
        use crate::classify::nn::classify_knn;
        use crate::measures::dtw::BandedDtw;

        let ds = synthetic::generate_scaled("SyntheticControl", 5, 24, 18).unwrap();
        let band = 6;
        let idx = Arc::new(Index::build(&ds.train, band, 2));
        for k in [1usize, 3] {
            let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
            let (eval, stats) = eng.classify(&ds.test, k, 2);
            let brute = classify_knn(&BandedDtw(band), &ds.train, &ds.test, k, 2);
            assert_eq!(eval.error_rate, brute.error_rate, "k={k}");
            assert!(stats.dp_cells < brute.visited_cells);
        }
    }

    #[test]
    fn order_by_lb_only_changes_work_not_results() {
        let ds = synthetic::generate_scaled("CBF", 31, 18, 12).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 4, 2));
        let ordered = SearchEngine::new(
            Arc::clone(&idx),
            Cascade {
                order_by_lb: true,
                ..Cascade::default()
            },
        );
        let scan = SearchEngine::new(
            Arc::clone(&idx),
            Cascade {
                order_by_lb: false,
                ..Cascade::default()
            },
        );
        for probe in &ds.test.series {
            let a = ordered.knn(probe, 3);
            let b = scan.knn(probe, 3);
            let ka: Vec<(u64, usize)> = a
                .neighbors
                .iter()
                .map(|n| (n.dist.to_bits(), n.train_idx))
                .collect();
            let kb: Vec<(u64, usize)> = b
                .neighbors
                .iter()
                .map(|n| (n.dist.to_bits(), n.train_idx))
                .collect();
            assert_eq!(ka, kb);
        }
    }

    #[test]
    fn from_spec_engine_matches_directly_built_engine() {
        use crate::measures::spec::{GridSpec, InlineGrids, MeasureSpec};
        let ds = synthetic::generate_scaled("CBF", 13, 14, 6).unwrap();
        let t = ds.series_len();
        // banded spec == Index::build
        let eng = SearchEngine::from_spec(
            &ds.train,
            &MeasureSpec::BandedDtw { band_cells: 3 },
            Cascade::default(),
            false,
            &InlineGrids,
            2,
        )
        .unwrap();
        let direct = SearchEngine::new(Arc::new(Index::build(&ds.train, 3, 2)), Cascade::default());
        for probe in &ds.test.series {
            let a = eng.knn(probe, 2);
            let b = direct.knn(probe, 2);
            for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
                assert_eq!(x.train_idx, y.train_idx);
            }
        }
        // spdtw spec over an inline corridor == Index::build_spdtw
        let sp = SearchEngine::from_spec(
            &ds.train,
            &MeasureSpec::SpDtw { grid: GridSpec::Corridor { t, band: 2 } },
            Cascade::default(),
            false,
            &InlineGrids,
            2,
        )
        .unwrap();
        let direct = SearchEngine::new(
            Arc::new(Index::build_spdtw(
                &ds.train,
                Arc::new(LocMatrix::corridor(t, 2)),
                2,
            )),
            Cascade::default(),
        );
        let a = sp.knn(&ds.test.series[0], 1);
        let b = direct.knn(&ds.test.series[0], 1);
        assert_eq!(a.neighbors[0].dist.to_bits(), b.neighbors[0].dist.to_bits());
        // non-searchable specs are typed errors
        assert!(SearchEngine::from_spec(
            &ds.train,
            &MeasureSpec::Corr,
            Cascade::default(),
            false,
            &InlineGrids,
            2
        )
        .is_err());
    }

    #[test]
    fn lane_width_is_invisible_in_results() {
        let ds = synthetic::generate_scaled("CBF", 27, 22, 12).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 5, 2));
        let scalar = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), 1);
        for lanes in [2usize, 4, 8, 99] {
            let eng = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), lanes);
            assert!((1..=MAX_LANES).contains(&eng.lanes), "width must clamp");
            for probe in &ds.test.series {
                for k in [1usize, 3] {
                    let a = scalar.knn(probe, k);
                    let b = eng.knn(probe, k);
                    let ka: Vec<(u64, usize)> = a
                        .neighbors
                        .iter()
                        .map(|n| (n.dist.to_bits(), n.train_idx))
                        .collect();
                    let kb: Vec<(u64, usize)> = b
                        .neighbors
                        .iter()
                        .map(|n| (n.dist.to_bits(), n.train_idx))
                        .collect();
                    assert_eq!(ka, kb, "lanes={lanes} k={k}");
                }
            }
        }
    }

    #[test]
    fn lane_groups_preserve_candidate_accounting() {
        let ds = synthetic::generate_scaled("SyntheticControl", 19, 21, 9).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 4, 2));
        for lanes in [1usize, 3, 8] {
            let eng = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), lanes);
            let (_, stats) = eng.classify(&ds.test, 2, 2);
            assert_eq!(
                stats.kim_pruned
                    + stats.keogh_pruned
                    + stats.rev_pruned
                    + stats.abandoned
                    + stats.full_evals,
                stats.candidates,
                "lanes={lanes}"
            );
        }
    }

    #[test]
    fn random_small_sets_fuzz_against_brute() {
        let mut rng = Pcg64::new(77);
        for case in 0..25 {
            let t = 4 + rng.below(12);
            let n = 3 + rng.below(8);
            let train = from_pairs(
                (0..n)
                    .map(|i| (i % 2, (0..t).map(|_| rng.normal()).collect()))
                    .collect(),
            );
            let band = 1 + rng.below(t);
            let idx = Arc::new(Index::build(&train, band, 1));
            let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
            let q: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let k = 1 + rng.below(n.min(4));
            let got = eng.knn_values(&q, k);
            let want = brute_topk(&idx, &q, k);
            for (g, (wd, wj)) in got.neighbors.iter().zip(&want) {
                assert_eq!(g.dist.to_bits(), wd.to_bits(), "case {case}");
                assert_eq!(g.train_idx, *wj, "case {case}");
            }
        }
    }

    #[test]
    fn precomputed_query_env_is_bit_identical_incl_stats() {
        use crate::measures::lb_keogh::envelope_into;
        use std::collections::VecDeque;
        let ds = synthetic::generate_scaled("CBF", 33, 16, 6).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 5, 2));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let (mut u, mut l) = (Vec::new(), Vec::new());
        let (mut maxq, mut minq) = (VecDeque::new(), VecDeque::new());
        let mut ws = crate::measures::workspace::DpWorkspace::new();
        for probe in &ds.test.series {
            envelope_into(&probe.values, idx.radius, &mut u, &mut l, &mut maxq, &mut minq);
            let a = eng.knn_values_with(&mut ws, &probe.values, 3);
            let b = eng.knn_values_with_query_env(&mut ws, &probe.values, 3, &u, &l);
            assert_eq!(a.stats, b.stats, "stats must match bitwise");
            assert_eq!(a.neighbors.len(), b.neighbors.len());
            for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
                assert_eq!(x.train_idx, y.train_idx);
            }
        }
    }

    #[test]
    fn knn_among_full_candidate_set_matches_full_search() {
        let ds = synthetic::generate_scaled("CBF", 35, 15, 5).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 4, 2));
        // the among-path is scalar and scans in the given order: compare
        // against the full path with ordering off and lanes == 1
        let cascade = Cascade {
            order_by_lb: false,
            ..Cascade::default()
        };
        let eng = SearchEngine::with_lanes(Arc::clone(&idx), cascade, 1);
        let all: Vec<usize> = (0..idx.len()).collect();
        let mut ws = crate::measures::workspace::DpWorkspace::new();
        for probe in &ds.test.series {
            let a = eng.knn_values_with(&mut ws, &probe.values, 2);
            let b = eng.knn_among_with(&mut ws, &probe.values, 2, &all);
            assert_eq!(a.stats, b.stats, "full candidate set must cost the same");
            for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
                assert_eq!(x.train_idx, y.train_idx);
            }
        }
    }

    #[test]
    fn knn_among_subset_is_exact_over_that_subset() {
        let ds = synthetic::generate_scaled("Gun-Point", 37, 14, 4).unwrap();
        let idx = Arc::new(Index::build(&ds.train, 6, 2));
        let eng = SearchEngine::new(Arc::clone(&idx), Cascade::default());
        let subset = [3usize, 0, 7, 5];
        let mut ws = crate::measures::workspace::DpWorkspace::new();
        for probe in &ds.test.series {
            let got = eng.knn_among_with(&mut ws, &probe.values, 2, &subset);
            let mut want = brute_topk(&idx, &probe.values, idx.len());
            want.retain(|&(_, j)| subset.contains(&j));
            want.truncate(2);
            assert_eq!(got.neighbors.len(), want.len());
            for (g, (wd, wj)) in got.neighbors.iter().zip(&want) {
                assert_eq!(g.dist.to_bits(), wd.to_bits());
                assert_eq!(g.train_idx, *wj);
            }
        }
    }
}
