//! Time-series data substrate: core types, z-normalization, UCR-format
//! IO, the Table-I dataset registry and the synthetic archive generators.

pub mod registry;
pub mod splits;
pub mod synthetic;
pub mod ucr;

/// A single univariate time series with a class label.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeSeries {
    pub label: usize,
    pub values: Vec<f64>,
}

impl TimeSeries {
    pub fn new(label: usize, values: Vec<f64>) -> Self {
        TimeSeries { label, values }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Z-normalize in place (mean 0, std 1).  Constant series are left
    /// centered at 0 (std guard), matching the UCR archive convention.
    pub fn znormalize(&mut self) {
        znormalize_in_place(&mut self.values);
    }

    /// Z-normalized copy.
    pub fn znormalized(&self) -> TimeSeries {
        let mut c = self.clone();
        c.znormalize();
        c
    }
}

/// Z-normalize a raw slice in place (mean 0, std 1; constant slices are
/// centered at 0) — the allocation-free core of
/// [`TimeSeries::znormalize`], used by the search engine to normalize
/// queries into a reused workspace buffer with bit-identical results.
pub fn znormalize_in_place(values: &mut [f64]) {
    let n = values.len();
    if n == 0 {
        return;
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    if std > 1e-12 {
        for v in values.iter_mut() {
            *v = (*v - mean) / std;
        }
    } else {
        for v in values.iter_mut() {
            *v -= mean;
        }
    }
}

/// A labeled set of equal-length series (one UCR split).
#[derive(Clone, Debug, Default)]
pub struct LabeledSet {
    pub series: Vec<TimeSeries>,
}

impl LabeledSet {
    pub fn new(series: Vec<TimeSeries>) -> Self {
        LabeledSet { series }
    }

    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Series length (asserts homogeneity in debug builds).
    pub fn series_len(&self) -> usize {
        let t = self.series.first().map(|s| s.len()).unwrap_or(0);
        debug_assert!(self.series.iter().all(|s| s.len() == t));
        t
    }

    /// Distinct labels, sorted.
    pub fn labels(&self) -> Vec<usize> {
        let mut l: Vec<usize> = self.series.iter().map(|s| s.label).collect();
        l.sort_unstable();
        l.dedup();
        l
    }

    pub fn znormalize(&mut self) {
        for s in &mut self.series {
            s.znormalize();
        }
    }
}

/// A full dataset: name + train/test splits.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: LabeledSet,
    pub test: LabeledSet,
}

impl Dataset {
    pub fn series_len(&self) -> usize {
        self.train.series_len()
    }

    pub fn n_classes(&self) -> usize {
        let mut l = self.train.labels();
        for s in &self.test.series {
            l.push(s.label);
        }
        l.sort_unstable();
        l.dedup();
        l.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn znorm_moments() {
        let mut s = TimeSeries::new(0, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        s.znormalize();
        let mean = s.values.iter().sum::<f64>() / 5.0;
        let var = s.values.iter().map(|v| v * v).sum::<f64>() / 5.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn znorm_constant_series_is_centered() {
        let mut s = TimeSeries::new(0, vec![3.0; 8]);
        s.znormalize();
        assert!(s.values.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn labels_sorted_dedup() {
        let set = LabeledSet::new(vec![
            TimeSeries::new(2, vec![0.0]),
            TimeSeries::new(0, vec![0.0]),
            TimeSeries::new(2, vec![0.0]),
        ]);
        assert_eq!(set.labels(), vec![0, 2]);
    }
}
