//! UCR time-series archive text format IO.
//!
//! The classic UCR format is one series per line: `label,v1,v2,...,vT`
//! (comma- or tab-separated; the 2015 archive uses commas, the 2018 one
//! tabs — we accept both and also whitespace).  Files written by
//! `write_split` round-trip losslessly through `read_split`.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::data::{Dataset, LabeledSet, TimeSeries};
use crate::error::{Error, Result};

/// Read one split (train or test file).
pub fn read_split(path: &Path) -> Result<LabeledSet> {
    let file = File::open(path)?;
    let reader = BufReader::new(file);
    let mut series = Vec::new();
    let mut expect_len: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = trimmed
            .split(|c: char| c == ',' || c == '\t' || c == ' ')
            .filter(|t| !t.is_empty())
            .collect();
        if toks.len() < 2 {
            return Err(Error::data(format!(
                "{}:{}: expected 'label,v1,...' got {} tokens",
                path.display(),
                lineno + 1,
                toks.len()
            )));
        }
        // UCR labels may be floats like "1.0" or negative ("-1"); map to
        // a usize by rounding and offsetting negatives.
        let raw: f64 = toks[0].parse().map_err(|_| {
            Error::data(format!("{}:{}: bad label '{}'", path.display(), lineno + 1, toks[0]))
        })?;
        let label = normalize_label(raw);
        let values: Result<Vec<f64>> = toks[1..]
            .iter()
            .map(|t| {
                t.parse::<f64>().map_err(|_| {
                    Error::data(format!("{}:{}: bad value '{t}'", path.display(), lineno + 1))
                })
            })
            .collect();
        let values = values?;
        if let Some(el) = expect_len {
            if values.len() != el {
                return Err(Error::data(format!(
                    "{}:{}: length {} != first series length {el}",
                    path.display(),
                    lineno + 1,
                    values.len()
                )));
            }
        } else {
            expect_len = Some(values.len());
        }
        series.push(TimeSeries::new(label, values));
    }
    if series.is_empty() {
        return Err(Error::data(format!("{}: empty split", path.display())));
    }
    Ok(LabeledSet::new(series))
}

/// Map a raw UCR float label to a stable usize (handles "-1", "1.0", ...).
fn normalize_label(raw: f64) -> usize {
    let r = raw.round() as i64;
    if r < 0 {
        (1_000_000 + (-r)) as usize // keep negatives distinct
    } else {
        r as usize
    }
}

/// Write one split in comma-separated UCR format.
pub fn write_split(path: &Path, set: &LabeledSet) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    for s in &set.series {
        write!(w, "{}", s.label)?;
        for v in &s.values {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Read `<dir>/<name>_TRAIN` + `<dir>/<name>_TEST` (UCR layout).
pub fn read_dataset(dir: &Path, name: &str) -> Result<Dataset> {
    let train = read_split(&dir.join(format!("{name}_TRAIN")))?;
    let test = read_split(&dir.join(format!("{name}_TEST")))?;
    if train.series_len() != test.series_len() {
        return Err(Error::data(format!(
            "{name}: train length {} != test length {}",
            train.series_len(),
            test.series_len()
        )));
    }
    Ok(Dataset {
        name: name.to_string(),
        train,
        test,
    })
}

/// Write a dataset in UCR layout.
pub fn write_dataset(dir: &Path, ds: &Dataset) -> Result<()> {
    write_split(&dir.join(format!("{}_TRAIN", ds.name)), &ds.train)?;
    write_split(&dir.join(format!("{}_TEST", ds.name)), &ds.test)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("spdtw_ucr_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_dataset() {
        let dir = tmpdir("rt");
        let ds = synthetic::generate_scaled("CBF", 1, 9, 6).unwrap();
        write_dataset(&dir, &ds).unwrap();
        let back = read_dataset(&dir, "CBF").unwrap();
        assert_eq!(back.train.len(), ds.train.len());
        assert_eq!(back.test.len(), ds.test.len());
        for (a, b) in back.train.series.iter().zip(&ds.train.series) {
            assert_eq!(a.label, b.label);
            for (x, y) in a.values.iter().zip(&b.values) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_tabs_and_float_labels() {
        let dir = tmpdir("tabs");
        let p = dir.join("X_TRAIN");
        std::fs::write(&p, "1.0\t0.5\t0.25\n-1\t1.5\t2.5\n").unwrap();
        let set = read_split(&p).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.series[0].label, 1);
        assert_ne!(set.series[1].label, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_ragged_and_empty() {
        let dir = tmpdir("bad");
        let p = dir.join("BAD_TRAIN");
        std::fs::write(&p, "1,1,2,3\n2,1,2\n").unwrap();
        assert!(read_split(&p).is_err());
        let e = dir.join("EMPTY_TRAIN");
        std::fs::write(&e, "\n\n").unwrap();
        assert!(read_split(&e).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = tmpdir("cmt");
        let p = dir.join("C_TRAIN");
        std::fs::write(&p, "# header\n\n0,1,2\n1,3,4\n").unwrap();
        let set = read_split(&p).unwrap();
        assert_eq!(set.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
