//! The 30-dataset inventory of the paper's Table I, together with the
//! generator family used to synthesize each dataset (DESIGN.md §2: the
//! real UCR archive is not available offline, so each entry is simulated
//! by a seeded, class-structured generator matching (k, N_train, N_test,
//! T) exactly).

/// Generator families — each produces class-separable series with
/// intra-class temporal warping, the property the paper's measures
/// exploit.  See `synthetic.rs` for definitions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Classic Cylinder-Bell-Funnel generator (the real CBF recipe).
    Cbf,
    /// Classic 6-class control-chart generator (the real SyntheticControl
    /// recipe); degenerates gracefully for other class counts.
    ControlChart,
    /// Sums of Gaussian bumps with class-specific centers/widths (leaf,
    /// shape outlines, arrowheads...).
    Bumps,
    /// Harmonic mixtures with class-specific frequencies and phases
    /// (sensor/spectro-style).
    Harmonics,
    /// Piecewise-constant device profiles with class-specific duty cycles
    /// (ElectricDevices/ScreenType-style).
    Device,
    /// Smoothed random-walk prototypes per class, warped per instance
    /// (the general-purpose family).
    WarpedWalk,
    /// Two-phase motion profiles (Gun-Point style: plateau + return).
    Motion,
    /// Spike trains with class-specific spike counts/positions
    /// (Lightning-style).
    Spikes,
}

/// One row of Table I.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub classes: usize,
    pub train: usize,
    pub test: usize,
    pub length: usize,
    pub family: Family,
}

/// The paper's Table I, verbatim (k, N_train, N_test, T).
pub const TABLE1: &[DatasetSpec] = &[
    DatasetSpec {
        name: "50Words",
        classes: 50,
        train: 450,
        test: 455,
        length: 270,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "Adiac",
        classes: 37,
        train: 390,
        test: 391,
        length: 176,
        family: Family::Harmonics,
    },
    DatasetSpec {
        name: "ArrowHead",
        classes: 3,
        train: 36,
        test: 175,
        length: 251,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "Beef",
        classes: 5,
        train: 30,
        test: 30,
        length: 470,
        family: Family::Harmonics,
    },
    DatasetSpec {
        name: "BeetleFly",
        classes: 2,
        train: 20,
        test: 20,
        length: 512,
        family: Family::WarpedWalk,
    },
    DatasetSpec {
        name: "BirdChicken",
        classes: 2,
        train: 20,
        test: 20,
        length: 512,
        family: Family::WarpedWalk,
    },
    DatasetSpec {
        name: "Car",
        classes: 4,
        train: 60,
        test: 60,
        length: 577,
        family: Family::Bumps,
    },
    DatasetSpec { name: "CBF", classes: 3, train: 30, test: 900, length: 128, family: Family::Cbf },
    DatasetSpec {
        name: "ECGFiveDays",
        classes: 2,
        train: 23,
        test: 861,
        length: 136,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "ElectricDevices",
        classes: 7,
        train: 8926,
        test: 7711,
        length: 96,
        family: Family::Device,
    },
    DatasetSpec {
        name: "FaceFour",
        classes: 4,
        train: 24,
        test: 88,
        length: 350,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "FacesUCR",
        classes: 14,
        train: 200,
        test: 2050,
        length: 131,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "Fish",
        classes: 7,
        train: 175,
        test: 175,
        length: 463,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "FordB",
        classes: 2,
        train: 810,
        test: 3636,
        length: 500,
        family: Family::Harmonics,
    },
    DatasetSpec {
        name: "Gun-Point",
        classes: 2,
        train: 50,
        test: 150,
        length: 150,
        family: Family::Motion,
    },
    DatasetSpec {
        name: "Ham",
        classes: 2,
        train: 109,
        test: 105,
        length: 431,
        family: Family::Harmonics,
    },
    DatasetSpec {
        name: "Haptics",
        classes: 5,
        train: 155,
        test: 308,
        length: 1092,
        family: Family::WarpedWalk,
    },
    DatasetSpec {
        name: "Herring",
        classes: 2,
        train: 64,
        test: 64,
        length: 512,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "InlineSkate",
        classes: 7,
        train: 100,
        test: 550,
        length: 1882,
        family: Family::WarpedWalk,
    },
    DatasetSpec {
        name: "Lighting-2",
        classes: 2,
        train: 60,
        test: 61,
        length: 637,
        family: Family::Spikes,
    },
    DatasetSpec {
        name: "Lighting-7",
        classes: 7,
        train: 70,
        test: 73,
        length: 319,
        family: Family::Spikes,
    },
    DatasetSpec {
        name: "MedicalImages",
        classes: 10,
        train: 381,
        test: 760,
        length: 99,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "OliveOil",
        classes: 4,
        train: 30,
        test: 30,
        length: 570,
        family: Family::Harmonics,
    },
    DatasetSpec {
        name: "OSULeaf",
        classes: 6,
        train: 200,
        test: 242,
        length: 427,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "ScreenType",
        classes: 3,
        train: 375,
        test: 375,
        length: 720,
        family: Family::Device,
    },
    DatasetSpec {
        name: "ShapesAll",
        classes: 60,
        train: 600,
        test: 600,
        length: 512,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "SwedishLeaf",
        classes: 15,
        train: 500,
        test: 625,
        length: 128,
        family: Family::Bumps,
    },
    DatasetSpec {
        name: "SyntheticControl",
        classes: 6,
        train: 300,
        test: 300,
        length: 60,
        family: Family::ControlChart,
    },
    DatasetSpec {
        name: "Trace",
        classes: 4,
        train: 100,
        test: 100,
        length: 275,
        family: Family::Motion,
    },
    DatasetSpec {
        name: "Wine",
        classes: 2,
        train: 57,
        test: 54,
        length: 234,
        family: Family::Harmonics,
    },
];

/// Look up a Table I spec by (case-insensitive) name.
pub fn find(name: &str) -> Option<&'static DatasetSpec> {
    TABLE1
        .iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

/// All dataset names in Table I order.
pub fn names() -> Vec<&'static str> {
    TABLE1.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirty_datasets() {
        assert_eq!(TABLE1.len(), 30);
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(find("cbf").is_some());
        assert!(find("Gun-Point").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn table1_spot_checks() {
        let s = find("InlineSkate").unwrap();
        assert_eq!((s.classes, s.train, s.test, s.length), (7, 100, 550, 1882));
        let s = find("SyntheticControl").unwrap();
        assert_eq!((s.classes, s.train, s.test, s.length), (6, 300, 300, 60));
        let s = find("ElectricDevices").unwrap();
        assert_eq!((s.classes, s.train, s.test, s.length), (7, 8926, 7711, 96));
    }

    #[test]
    fn names_unique() {
        let mut n = names();
        n.sort();
        n.dedup();
        assert_eq!(n.len(), 30);
    }
}
