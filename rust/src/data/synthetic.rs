//! Synthetic UCR archive (DESIGN.md §2 substitution).
//!
//! The real UCR archive is unavailable offline, so every Table-I dataset
//! is synthesized by a seeded, class-structured generator that matches
//! the paper's (k, N_train, N_test, T) exactly.  Design goals:
//!
//! 1. **Class structure**: each class has a stable prototype; instances
//!    are *time-warped* and noisy variants, so elastic measures (DTW
//!    family) genuinely outperform lock-step ones (Ed) — the property all
//!    of the paper's comparisons rest on.
//! 2. **Determinism**: a dataset is a pure function of (name, seed); the
//!    train/test streams are independent forks, so scaled subsets used by
//!    the default experiment runs are prefixes of the full data.
//! 3. **Family diversity**: eight generator families approximating the
//!    morphology of the corresponding UCR data (see `registry::Family`).
//!
//! Every emitted series is z-normalized, matching the UCR convention the
//! paper's Appendix A relies on (CORR ≡ Ed equivalence).

use crate::data::registry::{self, DatasetSpec, Family};
use crate::data::{Dataset, LabeledSet, TimeSeries};
use crate::error::{Error, Result};
use crate::util::rng::{hash64, Pcg64};

/// Generate the full dataset for a Table-I name.
pub fn generate(name: &str, seed: u64) -> Result<Dataset> {
    let spec = registry::find(name).ok_or_else(|| Error::Unknown {
        kind: "dataset",
        name: name.to_string(),
    })?;
    Ok(generate_with_sizes(spec, seed, spec.train, spec.test))
}

/// Generate with capped split sizes (stratified). Used by the scaled
/// experiment runs; the full run passes the Table-I sizes.
pub fn generate_scaled(
    name: &str,
    seed: u64,
    max_train: usize,
    max_test: usize,
) -> Result<Dataset> {
    let spec = registry::find(name).ok_or_else(|| Error::Unknown {
        kind: "dataset",
        name: name.to_string(),
    })?;
    let n_train = spec.train.min(max_train).max(spec.classes.min(spec.train));
    let n_test = spec.test.min(max_test).max(1);
    Ok(generate_with_sizes(spec, seed, n_train, n_test))
}

/// Generate `n_train`/`n_test` series for a spec (stratified labels).
pub fn generate_with_sizes(
    spec: &DatasetSpec,
    seed: u64,
    n_train: usize,
    n_test: usize,
) -> Dataset {
    let base = hash64(spec.name) ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut proto_rng = Pcg64::new(base);
    // Class prototypes are shared between splits (drawn once).
    let protos: Vec<ClassProto> = (0..spec.classes)
        .map(|c| ClassProto::draw(spec, c, &mut proto_rng))
        .collect();
    let mut train_rng = Pcg64::new(base ^ 0x7261_696e); // "rain"
    let mut test_rng = Pcg64::new(base ^ 0x7465_7374); // "test"
    let train = make_split(spec, &protos, n_train, &mut train_rng);
    let test = make_split(spec, &protos, n_test, &mut test_rng);
    Dataset {
        name: spec.name.to_string(),
        train,
        test,
    }
}

fn make_split(spec: &DatasetSpec, protos: &[ClassProto], n: usize, rng: &mut Pcg64) -> LabeledSet {
    let mut series = Vec::with_capacity(n);
    for i in 0..n {
        let label = i % spec.classes; // stratified round-robin
        let mut s = TimeSeries::new(label, protos[label].instance(spec, rng));
        s.znormalize();
        series.push(s);
    }
    // Shuffle so class order carries no information.
    rng.shuffle(&mut series);
    LabeledSet::new(series)
}

/// Per-class generator state.
enum ClassProto {
    Cbf { kind: usize },
    ControlChart { kind: usize },
    Bumps { centers: Vec<f64>, widths: Vec<f64>, amps: Vec<f64> },
    Harmonics { freqs: Vec<f64>, phases: Vec<f64>, amps: Vec<f64> },
    Device { edges: Vec<f64>, levels: Vec<f64> },
    WarpedWalk { proto: Vec<f64> },
    Motion { rise: f64, fall: f64, level: f64, sharp: f64 },
    Spikes { positions: Vec<f64>, signs: Vec<f64>, decay: f64 },
}

impl ClassProto {
    fn draw(spec: &DatasetSpec, class: usize, rng: &mut Pcg64) -> ClassProto {
        let mut r = rng.fork(class as u64 + 1);
        match spec.family {
            Family::Cbf => ClassProto::Cbf { kind: class % 3 },
            Family::ControlChart => ClassProto::ControlChart { kind: class % 6 },
            Family::Bumps => {
                let nb = 2 + (class % 4) + r.below(2);
                let centers = (0..nb).map(|_| r.range(0.08, 0.92)).collect();
                let widths = (0..nb).map(|_| r.range(0.02, 0.10)).collect();
                let amps = (0..nb)
                    .map(|_| r.range(0.5, 2.0) * if r.f64() < 0.25 { -1.0 } else { 1.0 })
                    .collect();
                ClassProto::Bumps { centers, widths, amps }
            }
            Family::Harmonics => {
                let nh = 3 + r.below(3);
                let freqs = (0..nh).map(|_| r.range(1.0, 9.0)).collect();
                let phases = (0..nh).map(|_| r.range(0.0, std::f64::consts::TAU)).collect();
                let amps = (0..nh).map(|_| r.range(0.3, 1.4)).collect();
                ClassProto::Harmonics { freqs, phases, amps }
            }
            Family::Device => {
                let ne = 2 + r.below(4);
                let mut edges: Vec<f64> = (0..ne).map(|_| r.range(0.05, 0.95)).collect();
                edges.sort_by(|a, b| a.total_cmp(b));
                let levels = (0..=ne)
                    .map(|_| {
                        if r.f64() < 0.5 {
                            r.range(0.0, 0.4)
                        } else {
                            r.range(1.2, 3.0)
                        }
                    })
                    .collect();
                ClassProto::Device { edges, levels }
            }
            Family::WarpedWalk => {
                let t = spec.length;
                let mut walk = Vec::with_capacity(t);
                let mut acc = 0.0;
                for _ in 0..t {
                    acc += r.normal();
                    walk.push(acc);
                }
                ClassProto::WarpedWalk { proto: smooth(&walk, (t / 20).max(3)) }
            }
            Family::Motion => ClassProto::Motion {
                rise: r.range(0.15, 0.40),
                fall: r.range(0.60, 0.85),
                level: r.range(1.0, 2.5),
                sharp: r.range(15.0, 60.0),
            },
            Family::Spikes => {
                let ns = 1 + class % 5 + r.below(2);
                let positions = (0..ns).map(|_| r.range(0.1, 0.9)).collect();
                let signs = (0..ns).map(|_| if r.f64() < 0.3 { -1.0 } else { 1.0 }).collect();
                ClassProto::Spikes { positions, signs, decay: r.range(30.0, 120.0) }
            }
        }
    }

    /// Draw one noisy, time-warped instance of this class.
    fn instance(&self, spec: &DatasetSpec, rng: &mut Pcg64) -> Vec<f64> {
        let t = spec.length;
        let noise = 0.25;
        match self {
            ClassProto::Cbf { kind } => cbf_instance(*kind, t, rng),
            ClassProto::ControlChart { kind } => control_chart_instance(*kind, t, rng),
            ClassProto::Bumps { centers, widths, amps } => {
                let shift = rng.range(-0.04, 0.04);
                let stretch = rng.range(0.92, 1.08);
                (0..t)
                    .map(|i| {
                        let u = i as f64 / (t - 1) as f64;
                        let mut v = 0.0;
                        for ((c, w), a) in centers.iter().zip(widths).zip(amps) {
                            let cc = (c * stretch + shift).clamp(0.0, 1.0);
                            let d = (u - cc) / w;
                            v += a * (-0.5 * d * d).exp();
                        }
                        v + noise * 0.4 * rng.normal()
                    })
                    .collect()
            }
            ClassProto::Harmonics { freqs, phases, amps } => {
                let phase_jit = rng.range(-0.35, 0.35);
                let freq_jit = rng.range(0.97, 1.03);
                (0..t)
                    .map(|i| {
                        let u = i as f64 / (t - 1) as f64;
                        let mut v = 0.0;
                        for ((f, p), a) in freqs.iter().zip(phases).zip(amps) {
                            v += a
                                * (std::f64::consts::TAU * f * freq_jit * u + p + phase_jit)
                                    .sin();
                        }
                        v + noise * 0.5 * rng.normal()
                    })
                    .collect()
            }
            ClassProto::Device { edges, levels } => {
                let jit: Vec<f64> = edges
                    .iter()
                    .map(|e| (e + rng.range(-0.05, 0.05)).clamp(0.0, 1.0))
                    .collect();
                (0..t)
                    .map(|i| {
                        let u = i as f64 / (t - 1) as f64;
                        let seg = jit.iter().filter(|&&e| u >= e).count();
                        levels[seg] + noise * 0.3 * rng.normal()
                    })
                    .collect()
            }
            ClassProto::WarpedWalk { proto } => {
                let warped = warp_resample(proto, t, rng, 0.35);
                warped.iter().map(|v| v + noise * 0.3 * rng.normal()).collect()
            }
            ClassProto::Motion { rise, fall, level, sharp } => {
                let r_jit = rise + rng.range(-0.05, 0.05);
                let f_jit = fall + rng.range(-0.05, 0.05);
                (0..t)
                    .map(|i| {
                        let u = i as f64 / (t - 1) as f64;
                        let up = 1.0 / (1.0 + (-sharp * (u - r_jit)).exp());
                        let down = 1.0 / (1.0 + (-sharp * (u - f_jit)).exp());
                        level * (up - down) + noise * 0.25 * rng.normal()
                    })
                    .collect()
            }
            ClassProto::Spikes { positions, signs, decay } => {
                let jit: Vec<f64> = positions
                    .iter()
                    .map(|p| (p + rng.range(-0.03, 0.03)).clamp(0.0, 1.0))
                    .collect();
                (0..t)
                    .map(|i| {
                        let u = i as f64 / (t - 1) as f64;
                        let mut v = 0.0;
                        for (p, s) in jit.iter().zip(signs) {
                            let d = (u - p).abs();
                            v += s * 3.0 * (-decay * d).exp();
                        }
                        v + noise * 0.35 * rng.normal()
                    })
                    .collect()
            }
        }
    }
}

/// Classic CBF generator (Saito 1994): class 0 cylinder, 1 bell, 2 funnel.
fn cbf_instance(kind: usize, t: usize, rng: &mut Pcg64) -> Vec<f64> {
    let a = rng.range(0.125, 0.375) * t as f64;
    let b = a + rng.range(0.25, 0.5) * t as f64;
    let amp = 6.0 + rng.normal();
    (0..t)
        .map(|i| {
            let x = i as f64;
            let inside = x >= a && x <= b;
            let shape = if !inside {
                0.0
            } else {
                match kind {
                    0 => 1.0,                       // cylinder
                    1 => (x - a) / (b - a),         // bell (ramp up)
                    _ => (b - x) / (b - a),         // funnel (ramp down)
                }
            };
            amp * shape + rng.normal()
        })
        .collect()
}

/// Classic control-chart patterns (Alcock & Manolopoulos 1999).
fn control_chart_instance(kind: usize, t: usize, rng: &mut Pcg64) -> Vec<f64> {
    let shift_point = rng.range(0.33, 0.66) * t as f64;
    (0..t)
        .map(|i| {
            let x = i as f64;
            let base = 30.0 + 2.0 * rng.normal();
            match kind {
                0 => base,                                                   // normal
                // cyclic
                1 => {
                    base + 8.0 * (std::f64::consts::TAU * x / rng.range(10.0, 15.0).max(1.0)).sin()
                }
                2 => base + 0.4 * x,                                         // increasing trend
                3 => base - 0.4 * x,                                         // decreasing trend
                4 => base + if x >= shift_point { 10.0 } else { 0.0 },       // upward shift
                _ => base - if x >= shift_point { 10.0 } else { 0.0 },       // downward shift
            }
        })
        .collect()
}

/// Moving-average smoother (reflective bounds).
fn smooth(xs: &[f64], w: usize) -> Vec<f64> {
    let n = xs.len();
    (0..n)
        .map(|i| {
            let lo = i.saturating_sub(w);
            let hi = (i + w + 1).min(n);
            xs[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect()
}

/// Random smooth monotone time warp: resample `proto` (any length) to
/// length `t` along a warped time axis.  `strength` in [0, 1) controls
/// deviation from identity.
fn warp_resample(proto: &[f64], t: usize, rng: &mut Pcg64, strength: f64) -> Vec<f64> {
    let knots = 8;
    // Positive increments -> monotone warp; normalized to [0,1].
    let mut incs: Vec<f64> = (0..knots)
        .map(|_| (1.0 - strength) + strength * rng.range(0.0, 2.0))
        .collect();
    let total: f64 = incs.iter().sum();
    for v in &mut incs {
        *v /= total;
    }
    let mut cum = vec![0.0];
    for v in &incs {
        cum.push(cum.last().unwrap() + v);
    }
    let n = proto.len();
    (0..t)
        .map(|i| {
            let u = i as f64 / (t - 1).max(1) as f64;
            // piecewise-linear warp through the knots
            let seg = ((u * knots as f64).floor() as usize).min(knots - 1);
            let frac = u * knots as f64 - seg as f64;
            let wu = cum[seg] + frac * (cum[seg + 1] - cum[seg]);
            let pos = wu * (n - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = (lo + 1).min(n - 1);
            let f = pos - lo as f64;
            proto[lo] * (1.0 - f) + proto[hi] * f
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate("CBF", 42).unwrap();
        let b = generate("CBF", 42).unwrap();
        assert_eq!(a.train.series[0].values, b.train.series[0].values);
        assert_eq!(a.test.series[5].label, b.test.series[5].label);
    }

    #[test]
    fn seeds_differ() {
        let a = generate("CBF", 1).unwrap();
        let b = generate("CBF", 2).unwrap();
        assert_ne!(a.train.series[0].values, b.train.series[0].values);
    }

    #[test]
    fn sizes_match_table1() {
        for name in ["CBF", "SyntheticControl", "Gun-Point", "Wine"] {
            let spec = registry::find(name).unwrap();
            let ds = generate(name, 7).unwrap();
            assert_eq!(ds.train.len(), spec.train, "{name} train");
            assert_eq!(ds.test.len(), spec.test, "{name} test");
            assert_eq!(ds.series_len(), spec.length, "{name} length");
            assert_eq!(ds.n_classes(), spec.classes, "{name} classes");
        }
    }

    #[test]
    fn scaled_sizes_and_stratification() {
        let ds = generate_scaled("SwedishLeaf", 3, 60, 45).unwrap();
        assert_eq!(ds.train.len(), 60);
        assert_eq!(ds.test.len(), 45);
        // all 15 classes present in train (60 = 4 per class)
        assert_eq!(ds.train.labels().len(), 15);
    }

    #[test]
    fn series_are_znormalized() {
        let ds = generate("Beef", 11).unwrap();
        for s in ds.train.series.iter().take(5) {
            let m: f64 = s.values.iter().sum::<f64>() / s.len() as f64;
            let v: f64 = s.values.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / s.len() as f64;
            assert!(m.abs() < 1e-9);
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn all_thirty_generate_quickly_scaled() {
        for spec in registry::TABLE1 {
            let ds = generate_scaled(spec.name, 5, 12, 6).unwrap();
            assert!(ds.train.len() >= spec.classes.min(12));
            assert_eq!(ds.series_len(), spec.length);
        }
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(generate("NotADataset", 0).is_err());
    }

    #[test]
    fn classes_are_separable_by_euclid_on_average() {
        // weak sanity: intra-class distance < inter-class distance in
        // the mean, otherwise classification results are meaningless.
        let ds = generate_scaled("CBF", 9, 30, 0).unwrap();
        let series = &ds.train.series;
        let (mut intra, mut inter, mut ni, mut nx) = (0.0, 0.0, 0usize, 0usize);
        for i in 0..series.len() {
            for j in (i + 1)..series.len() {
                let d: f64 = series[i]
                    .values
                    .iter()
                    .zip(&series[j].values)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if series[i].label == series[j].label {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        assert!(intra / ni as f64 <= inter / nx as f64);
    }

    #[test]
    fn warp_resample_preserves_endpoints_roughly() {
        let proto: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let mut rng = Pcg64::new(3);
        let w = warp_resample(&proto, 50, &mut rng, 0.3);
        assert_eq!(w.len(), 50);
        assert!((w[0] - 0.0).abs() < 1e-9);
        assert!((w[49] - 99.0).abs() < 1e-9);
        // monotone
        for i in 1..50 {
            assert!(w[i] >= w[i - 1] - 1e-9);
        }
    }
}
