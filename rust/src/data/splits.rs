//! Split utilities: stratified subsampling and k-fold partitions used by
//! the tuning (cross-validation) and the scaled experiment runs.

use crate::data::{LabeledSet, TimeSeries};
use crate::util::rng::Pcg64;

/// Stratified subsample of at most `max` series (keeps class proportions,
/// ensures every present class keeps at least one instance when possible).
pub fn stratified_subsample(set: &LabeledSet, max: usize, seed: u64) -> LabeledSet {
    if set.len() <= max {
        return set.clone();
    }
    let mut rng = Pcg64::new(seed);
    let labels = set.labels();
    let mut by_class: Vec<Vec<usize>> = labels.iter().map(|_| Vec::new()).collect();
    for (i, s) in set.series.iter().enumerate() {
        let ci = labels.binary_search(&s.label).unwrap();
        by_class[ci].push(i);
    }
    for idxs in &mut by_class {
        rng.shuffle(idxs);
    }
    // Round-robin across classes until `max` picks.
    let mut picks: Vec<usize> = Vec::with_capacity(max);
    let mut cursor = vec![0usize; by_class.len()];
    'outer: loop {
        let mut progressed = false;
        for (c, idxs) in by_class.iter().enumerate() {
            if cursor[c] < idxs.len() {
                picks.push(idxs[cursor[c]]);
                cursor[c] += 1;
                progressed = true;
                if picks.len() == max {
                    break 'outer;
                }
            }
        }
        if !progressed {
            break;
        }
    }
    picks.sort_unstable();
    LabeledSet::new(picks.into_iter().map(|i| set.series[i].clone()).collect())
}

/// Deterministic k-fold partition indices (stratified by label).
/// Returns for each fold the (train_indices, valid_indices).
pub fn kfold_indices(set: &LabeledSet, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2);
    let k = k.min(set.len().max(2));
    let mut rng = Pcg64::new(seed ^ 0xf01d);
    let labels = set.labels();
    let mut by_class: Vec<Vec<usize>> = labels.iter().map(|_| Vec::new()).collect();
    for (i, s) in set.series.iter().enumerate() {
        let ci = labels.binary_search(&s.label).unwrap();
        by_class[ci].push(i);
    }
    let mut fold_of = vec![0usize; set.len()];
    for idxs in &mut by_class {
        rng.shuffle(idxs);
        for (pos, &i) in idxs.iter().enumerate() {
            fold_of[i] = pos % k;
        }
    }
    (0..k)
        .map(|f| {
            let valid: Vec<usize> = (0..set.len()).filter(|&i| fold_of[i] == f).collect();
            let train: Vec<usize> = (0..set.len()).filter(|&i| fold_of[i] != f).collect();
            (train, valid)
        })
        .collect()
}

/// Materialize a subset of a LabeledSet by indices.
pub fn subset(set: &LabeledSet, idxs: &[usize]) -> LabeledSet {
    LabeledSet::new(idxs.iter().map(|&i| set.series[i].clone()).collect())
}

/// Build a LabeledSet from raw (label, values) pairs — test helper.
pub fn from_pairs(pairs: Vec<(usize, Vec<f64>)>) -> LabeledSet {
    LabeledSet::new(
        pairs
            .into_iter()
            .map(|(l, v)| TimeSeries::new(l, v))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, classes: usize) -> LabeledSet {
        from_pairs((0..n).map(|i| (i % classes, vec![i as f64, 0.0])).collect())
    }

    #[test]
    fn subsample_keeps_classes() {
        let set = toy(100, 5);
        let sub = stratified_subsample(&set, 20, 1);
        assert_eq!(sub.len(), 20);
        assert_eq!(sub.labels().len(), 5);
    }

    #[test]
    fn subsample_noop_when_small() {
        let set = toy(10, 2);
        let sub = stratified_subsample(&set, 50, 1);
        assert_eq!(sub.len(), 10);
    }

    #[test]
    fn kfold_partitions_cover_everything_once() {
        let set = toy(53, 4);
        let folds = kfold_indices(&set, 5, 3);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; set.len()];
        for (train, valid) in &folds {
            assert_eq!(train.len() + valid.len(), set.len());
            for &i in valid {
                seen[i] += 1;
                assert!(!train.contains(&i));
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "each index in exactly one validation fold");
    }

    #[test]
    fn kfold_deterministic() {
        let set = toy(30, 3);
        assert_eq!(kfold_indices(&set, 3, 7), kfold_indices(&set, 3, 7));
    }
}
