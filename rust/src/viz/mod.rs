//! Grid visualization (paper Figs. 5-8): PGM/PPM image writers and ASCII
//! heatmaps of occupancy grids / corridors / thresholded LOC matrices.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;
use crate::sparse::{LocMatrix, OccupancyGrid};

/// A dense grayscale intensity grid in [0, 1], row-major.
#[derive(Clone, Debug)]
pub struct Heatmap {
    pub t: usize,
    pub values: Vec<f64>,
}

impl Heatmap {
    pub fn from_occupancy(grid: &OccupancyGrid) -> Heatmap {
        let m = grid.max_count().max(1) as f64;
        Heatmap {
            t: grid.t,
            values: grid.counts.iter().map(|&c| c as f64 / m).collect(),
        }
    }

    pub fn from_loc(loc: &LocMatrix) -> Heatmap {
        let mut values = vec![0.0; loc.t * loc.t];
        let wmax = loc
            .weights
            .iter()
            .cloned()
            .fold(0.0f64, f64::max)
            .max(1e-12);
        for (r, c, w, _) in loc.iter_cells() {
            values[r * loc.t + c] = (w / wmax).clamp(0.0, 1.0);
        }
        Heatmap { t: loc.t, values }
    }

    /// Binary support map of a LOC matrix (cells in P = 1).
    pub fn from_loc_support(loc: &LocMatrix) -> Heatmap {
        let mut values = vec![0.0; loc.t * loc.t];
        for (r, c, _, _) in loc.iter_cells() {
            values[r * loc.t + c] = 1.0;
        }
        Heatmap { t: loc.t, values }
    }

    /// Sakoe-Chiba corridor map for comparison panels.
    pub fn corridor(t: usize, band: usize) -> Heatmap {
        let mut values = vec![0.0; t * t];
        for i in 0..t {
            let lo = i.saturating_sub(band);
            let hi = (i + band).min(t - 1);
            for j in lo..=hi {
                values[i * t + j] = 1.0;
            }
        }
        Heatmap { t, values }
    }

    /// Write a binary PGM (grayscale) image, optionally downsampled to at
    /// most `max_px` pixels per side.
    pub fn write_pgm(&self, path: &Path, max_px: usize) -> Result<()> {
        let (side, img) = self.downsample(max_px);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        write!(w, "P5\n{side} {side}\n255\n")?;
        let bytes: Vec<u8> = img
            .iter()
            .map(|&v| (255.0 * (1.0 - v.clamp(0.0, 1.0))) as u8) // dark = occupied
            .collect();
        w.write_all(&bytes)?;
        Ok(())
    }

    /// Write a color PPM using a blue→yellow→red colormap.
    pub fn write_ppm(&self, path: &Path, max_px: usize) -> Result<()> {
        let (side, img) = self.downsample(max_px);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        write!(w, "P6\n{side} {side}\n255\n")?;
        let mut bytes = Vec::with_capacity(side * side * 3);
        for &v in &img {
            let (r, g, b) = colormap(v.clamp(0.0, 1.0));
            bytes.extend_from_slice(&[r, g, b]);
        }
        w.write_all(&bytes)?;
        Ok(())
    }

    /// ASCII rendering (for terminals / EXPERIMENTS.md), `width` chars.
    pub fn ascii(&self, width: usize) -> String {
        let (side, img) = self.downsample(width);
        let ramp: &[u8] = b" .:-=+*#%@";
        let mut out = String::with_capacity(side * (side + 1));
        for r in 0..side {
            for c in 0..side {
                let v = img[r * side + c].clamp(0.0, 1.0);
                let idx = ((v * (ramp.len() - 1) as f64).round()) as usize;
                out.push(ramp[idx] as char);
            }
            out.push('\n');
        }
        out
    }

    /// Box-average downsample to at most `max_px` per side.
    fn downsample(&self, max_px: usize) -> (usize, Vec<f64>) {
        let t = self.t;
        if t <= max_px {
            return (t, self.values.clone());
        }
        let side = max_px.max(1);
        let mut out = vec![0.0f64; side * side];
        let scale = t as f64 / side as f64;
        for r in 0..side {
            for c in 0..side {
                let r0 = (r as f64 * scale) as usize;
                let r1 = (((r + 1) as f64 * scale) as usize).min(t).max(r0 + 1);
                let c0 = (c as f64 * scale) as usize;
                let c1 = (((c + 1) as f64 * scale) as usize).min(t).max(c0 + 1);
                let mut acc = 0.0;
                for i in r0..r1 {
                    for j in c0..c1 {
                        acc += self.values[i * t + j];
                    }
                }
                out[r * side + c] = acc / ((r1 - r0) * (c1 - c0)) as f64;
            }
        }
        (side, out)
    }
}

/// Blue (cold) → yellow → red (hot) colormap.
fn colormap(v: f64) -> (u8, u8, u8) {
    if v <= 0.0 {
        return (250, 250, 252); // near-white background
    }
    let (r, g, b) = if v < 0.5 {
        let u = v / 0.5;
        (u, u, 1.0 - u) // blue -> yellow
    } else {
        let u = (v - 0.5) / 0.5;
        (1.0, 1.0 - u, 0.0) // yellow -> red
    };
    ((r * 255.0) as u8, (g * 255.0) as u8, (b * 255.0) as u8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_has_expected_shape() {
        let hm = Heatmap::corridor(20, 2);
        let a = hm.ascii(20);
        assert_eq!(a.lines().count(), 20);
        assert!(a.contains('@')); // band cells saturate the ramp
        assert!(a.contains(' ')); // off-band cells empty
    }

    #[test]
    fn downsample_bounds() {
        let hm = Heatmap::corridor(100, 5);
        let (side, img) = hm.downsample(32);
        assert_eq!(side, 32);
        assert_eq!(img.len(), 32 * 32);
        assert!(img.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn pgm_ppm_written() {
        let dir = std::env::temp_dir().join(format!("spdtw_viz_{}", std::process::id()));
        let hm = Heatmap::corridor(30, 3);
        let pgm = dir.join("x.pgm");
        let ppm = dir.join("x.ppm");
        hm.write_pgm(&pgm, 16).unwrap();
        hm.write_ppm(&ppm, 16).unwrap();
        let head = std::fs::read(&pgm).unwrap();
        assert_eq!(&head[..2], b"P5");
        let head = std::fs::read(&ppm).unwrap();
        assert_eq!(&head[..2], b"P6");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_loc_support_binary() {
        let loc = crate::sparse::LocMatrix::corridor(8, 1);
        let hm = Heatmap::from_loc_support(&loc);
        let ones = hm.values.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(ones, loc.nnz());
    }
}
