//! Wire-protocol compatibility suite: golden assertions that the v1
//! bare-op protocol keeps answering exactly as before the v2 redesign,
//! and that the v2 envelope honors its contract — `id` echo on success
//! and error, generic `dist`/`kernel`/`register_measure` ops reaching
//! every measure, and a stable machine-readable `code` on every
//! malformed-request class.

use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::TimeSeries;
use spdtw::measures::dtw::dtw_banded;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::sparse::LocMatrix;
use spdtw::util::json::Json;

fn start() -> (Server, Client) {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let server = Server::start(coord, "127.0.0.1:0").unwrap();
    let client = Client::connect(&server.addr).unwrap();
    (server, client)
}

fn call(client: &mut Client, req: &str) -> Json {
    client.call(&Json::parse(req).unwrap()).unwrap()
}

// ---------------------------------------------------------------------------
// v1 golden suite: bare ops answer with the exact pre-v2 reply fields
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn v1_bare_ops_answer_identically() {
    let (mut server, mut client) = start();

    // ping
    let r = call(&mut client, r#"{"op":"ping"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
    assert!(r.get("id").is_none(), "no id sent, none echoed");

    // info
    let r = call(&mut client, r#"{"op":"info"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    for field in ["workers", "batch_size", "prefer_pjrt", "completed"] {
        assert!(r.get(field).is_some(), "info field {field}");
    }

    // register_grid -> spdtw
    let r = call(&mut client, r#"{"op":"register_grid","t":4,"band":1}"#);
    let gid = r.req_usize("grid").unwrap();
    let r = call(
        &mut client,
        &format!(r#"{{"op":"spdtw","grid":{gid},"x":[0,1,2,3],"y":[0,1,2,3]}}"#),
    );
    assert_eq!(r.req_f64("value").unwrap(), 0.0);
    assert_eq!(r.req_str("backend").unwrap(), "native");
    assert!(r.req_f64("cells").unwrap() > 0.0);

    // spkrdtw
    let r = call(
        &mut client,
        &format!(r#"{{"op":"spkrdtw","grid":{gid},"nu":0.5,"x":[0,1,2,3],"y":[0,1,2,3]}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert!(r.get("log_k").is_some());

    // register_index reply carries the full PR-2/PR-4 field set
    let r = call(
        &mut client,
        concat!(
            r#"{"op":"register_index","band":1,"#,
            r#""series":[[0,0,0],[5,5,5]],"labels":[0,1]}"#
        ),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let idx = r.req_usize("index").unwrap();
    assert_eq!(r.get("loaded_from_disk"), Some(&Json::Bool(false)));
    assert_eq!(r.get("drift"), Some(&Json::Bool(false)));
    assert_eq!(r.req_str("content_hash").unwrap().len(), 16);
    assert!(r.req_f64("memory_bytes").unwrap() > 0.0);

    // search
    let r = call(
        &mut client,
        &format!(r#"{{"op":"search","index":{idx},"k":1,"x":[0,0,0]}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let ns = r.req_arr("neighbors").unwrap();
    assert_eq!(ns[0].req_f64("dist").unwrap(), 0.0);
    for field in ["candidates", "pruned", "full_evals", "dp_cells"] {
        assert!(r.get(field).is_some(), "search field {field}");
    }

    // batch_search
    let r = call(
        &mut client,
        &format!(r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[[0,0,0],[5,5,5]]}}"#),
    );
    assert_eq!(r.req_usize("queries").unwrap(), 2);
    assert_eq!(r.req_arr("results").unwrap().len(), 2);

    // metrics keeps every pre-v2 field
    let r = call(&mut client, r#"{"op":"metrics"}"#);
    for field in [
        "submitted",
        "completed",
        "failed",
        "native",
        "pjrt",
        "batches",
        "padded",
        "search_batches",
        "requests_inflight",
        "peak_concurrent_requests",
        "pool_epochs_live",
        "pool_peak_epochs",
        "native_queue_depth",
        "index_evictions",
        "mean_latency_us",
    ] {
        assert!(r.get(field).is_some(), "metrics field {field}");
    }

    // v1 error shape: ok:false + error string (code is additive)
    let r = call(&mut client, r#"{"op":"nosuchop"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get("error").is_some());

    server.stop();
}

// ---------------------------------------------------------------------------
// v2 envelope: id echo + every v1 op still served
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn v2_envelope_echoes_id_on_success_and_error() {
    let (mut server, mut client) = start();

    // string id on success
    let r = call(&mut client, r#"{"proto":2,"id":"req-1","op":"ping"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("id"), Some(&Json::Str("req-1".into())));

    // numeric id, v1 op under the envelope
    let r = call(&mut client, r#"{"proto":2,"id":17,"op":"register_grid","t":4,"band":1}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("id"), Some(&Json::Num(17.0)));
    let gid = r.req_usize("grid").unwrap();
    let r = call(
        &mut client,
        &format!(r#"{{"proto":2,"id":18,"op":"spdtw","grid":{gid},"x":[0,1,2,3],"y":[0,1,2,3]}}"#),
    );
    assert_eq!(r.req_f64("value").unwrap(), 0.0);
    assert_eq!(r.get("id"), Some(&Json::Num(18.0)));

    // id echoed on errors too
    let r = call(&mut client, r#"{"proto":2,"id":"oops","op":"nosuchop"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.get("id"), Some(&Json::Str("oops".into())));
    assert_eq!(r.req_str("code").unwrap(), "unknown_op");

    // explicit proto:1 is the legacy protocol, still fine
    let r = call(&mut client, r#"{"proto":1,"op":"ping"}"#);
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));

    // v2 requests are counted
    let m = call(&mut client, r#"{"op":"metrics"}"#);
    assert!(m.req_f64("proto_v2_requests").unwrap() >= 4.0);

    server.stop();
}

// ---------------------------------------------------------------------------
// v2 generic ops: dist / kernel / register_measure reach every measure
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn v2_generic_dist_and_kernel_match_direct_evaluation() {
    let (mut server, mut client) = start();
    let x = [0.0, 1.0, 2.5, 3.0, 2.0, 1.0];
    let y = [0.5, 1.5, 2.0, 3.5, 2.5, 0.0];
    let xj = "[0,1,2.5,3,2,1]";
    let yj = "[0.5,1.5,2,3.5,2.5,0]";

    // banded DTW through the generic op, bit-compared to the library
    let r = call(
        &mut client,
        &format!(
            r#"{{"proto":2,"op":"dist","measure":{{"kind":"banded_dtw","band_cells":2}},"x":{xj},"y":{yj}}}"#
        ),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    let want = dtw_banded(&x, &y, 2);
    assert_eq!(r.req_f64("value").unwrap().to_bits(), want.value.to_bits());
    assert_eq!(r.req_f64("cells").unwrap() as u64, want.visited_cells);
    assert_eq!(r.req_str("backend").unwrap(), "native");

    // sakoe_chiba + euclidean + itakura all answer
    for kind in [
        r#"{"kind":"sakoe_chiba","band_pct":20}"#,
        r#"{"kind":"euclidean"}"#,
        r#"{"kind":"itakura"}"#,
        r#"{"kind":"minkowski","p":1}"#,
    ] {
        let r = call(
            &mut client,
            &format!(r#"{{"proto":2,"op":"dist","measure":{kind},"x":{xj},"y":{yj}}}"#),
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{kind}: {r:?}");
        assert!(r.req_f64("value").unwrap() >= 0.0);
    }

    // spdtw over a registered grid == the v1 spdtw op
    let g = call(&mut client, r#"{"proto":2,"op":"register_grid","t":6,"band":2}"#);
    let gid = g.req_usize("grid").unwrap();
    let generic = call(
        &mut client,
        &format!(
            r#"{{"proto":2,"op":"dist","measure":{{"kind":"spdtw","grid":{{"kind":"registered","key":{gid}}}}},"x":{xj},"y":{yj}}}"#
        ),
    );
    let v1 = call(
        &mut client,
        &format!(r#"{{"op":"spdtw","grid":{gid},"x":{xj},"y":{yj}}}"#),
    );
    assert_eq!(
        generic.req_f64("value").unwrap().to_bits(),
        v1.req_f64("value").unwrap().to_bits(),
        "generic dist and v1 spdtw must agree bitwise"
    );

    // spdtw over an inline corridor grid == SpDtw on the same corridor
    let inline = call(
        &mut client,
        &format!(
            r#"{{"proto":2,"op":"dist","measure":{{"kind":"spdtw","grid":{{"kind":"corridor","t":6,"band":2}}}},"x":{xj},"y":{yj}}}"#
        ),
    );
    let direct = SpDtw::new(LocMatrix::corridor(6, 2)).dist(
        &TimeSeries::new(0, x.to_vec()),
        &TimeSeries::new(0, y.to_vec()),
    );
    assert_eq!(
        inline.req_f64("value").unwrap().to_bits(),
        direct.value.to_bits()
    );

    // kernel op matches the library log-kernel; dist on the same
    // kernel spec is the normalized distance (0 on self)
    let r = call(
        &mut client,
        &format!(r#"{{"proto":2,"op":"kernel","measure":{{"kind":"krdtw","nu":0.5}},"x":{xj},"y":{yj}}}"#),
    );
    let want = Krdtw::new(0.5).log_kernel(&x, &y);
    assert_eq!(r.req_f64("log_k").unwrap().to_bits(), want.value.to_bits());
    let r = call(
        &mut client,
        &format!(r#"{{"proto":2,"op":"dist","measure":{{"kind":"krdtw","nu":0.5}},"x":{xj},"y":{xj}}}"#),
    );
    assert!(r.req_f64("value").unwrap().abs() < 1e-9);

    // register_measure: key-addressed dist answers identically to the
    // inline spec
    let reg = call(
        &mut client,
        r#"{"proto":2,"op":"register_measure","measure":{"kind":"banded_dtw","band_cells":2}}"#,
    );
    assert_eq!(reg.get("ok"), Some(&Json::Bool(true)), "{reg:?}");
    assert_eq!(reg.get("kernel"), Some(&Json::Bool(false)));
    assert_eq!(reg.req_str("name").unwrap(), "DTW_band(2)");
    let mkey = reg.req_usize("measure").unwrap();
    let r = call(
        &mut client,
        &format!(r#"{{"proto":2,"op":"dist","measure":{mkey},"x":{xj},"y":{yj}}}"#),
    );
    assert_eq!(r.req_f64("value").unwrap().to_bits(), want_banded(&x, &y));

    // kernel on a distance measure: typed bad_request
    let r = call(
        &mut client,
        &format!(r#"{{"proto":2,"op":"kernel","measure":{mkey},"x":{xj},"y":{yj}}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.req_str("code").unwrap(), "bad_request");

    // v2 register_index with a measure spec serves searches
    let reg = call(
        &mut client,
        concat!(
            r#"{"proto":2,"op":"register_index","#,
            r#""measure":{"kind":"banded_dtw","band_cells":1},"#,
            r#""series":[[0,0,0],[5,5,5]],"labels":[0,1]}"#
        ),
    );
    assert_eq!(reg.get("ok"), Some(&Json::Bool(true)), "{reg:?}");
    let idx = reg.req_usize("index").unwrap();
    let s = call(
        &mut client,
        &format!(r#"{{"proto":2,"op":"search","index":{idx},"k":1,"x":[0,0,0]}}"#),
    );
    assert_eq!(s.req_arr("neighbors").unwrap()[0].req_f64("dist").unwrap(), 0.0);

    let m = call(&mut client, r#"{"op":"metrics"}"#);
    assert_eq!(m.req_f64("measures_registered").unwrap(), 1.0);

    server.stop();
}

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn named_register_index_flags_measure_family_drift() {
    let (mut server, mut client) = start();
    let reg = |measure: &str| {
        format!(
            r#"{{"proto":2,"op":"register_index","name":"fam","measure":{measure},"series":[[0,0,0],[5,5,5]],"labels":[0,1]}}"#
        )
    };
    // cold build under banded_dtw(1)
    let r = call(&mut client, &reg(r#"{"kind":"banded_dtw","band_cells":1}"#));
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("drift"), Some(&Json::Bool(false)));

    // same name + same family: served from the registry, no drift
    let r = call(&mut client, &reg(r#"{"kind":"banded_dtw","band_cells":1}"#));
    assert_eq!(r.get("loaded_from_disk"), Some(&Json::Bool(false)));
    assert_eq!(r.get("measure_drift"), Some(&Json::Bool(false)), "{r:?}");
    assert_eq!(r.get("drift"), Some(&Json::Bool(false)));

    // same payload, DIFFERENT measure family: content hash cannot see
    // it, measure_drift must
    let r = call(&mut client, &reg(r#"{"kind":"banded_dtw","band_cells":2}"#));
    assert_eq!(r.get("drift"), Some(&Json::Bool(false)), "payload unchanged");
    assert_eq!(r.get("measure_drift"), Some(&Json::Bool(true)), "{r:?}");
    let r = call(
        &mut client,
        &reg(r#"{"kind":"spdtw","grid":{"kind":"corridor","t":3,"band":1}}"#),
    );
    assert_eq!(r.get("measure_drift"), Some(&Json::Bool(true)), "{r:?}");

    // an invalid measure spec is rejected even on the named shortcut
    let r = call(&mut client, &reg(r#"{"kind":"krdtw","nu":-1}"#));
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.req_str("code").unwrap(), "bad_request");

    // a v1-style named re-register (no measure field) stays untouched:
    // no measure_drift key at all
    let r = call(
        &mut client,
        r#"{"op":"register_index","name":"fam","band":1,"series":[[0,0,0],[5,5,5]],"labels":[0,1]}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert!(r.get("measure_drift").is_none());
    server.stop();
}

fn want_banded(x: &[f64], y: &[f64]) -> u64 {
    dtw_banded(x, y, 2).value.to_bits()
}

// ---------------------------------------------------------------------------
// stable error codes for every malformed-request class
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn error_codes_are_stable_per_malformed_class() {
    let (mut server, mut client) = start();

    // bad_json cannot go through Client (it serializes valid JSON):
    // write the raw line ourselves
    {
        use std::io::{BufRead, BufReader, Write};
        let stream = std::net::TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(b"this is not json\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.req_str("code").unwrap(), "bad_json");
    }

    let idx_req = concat!(
        r#"{"op":"register_index","band":1,"#,
        r#""series":[[0,0,0],[5,5,5]],"labels":[0,1]}"#
    );
    let idx = call(&mut client, idx_req).req_usize("index").unwrap();

    let cases: Vec<(String, &str)> = vec![
        // unsupported proto
        (r#"{"proto":3,"op":"ping"}"#.into(), "unsupported_proto"),
        (r#"{"proto":"two","op":"ping"}"#.into(), "unsupported_proto"),
        // missing / unknown op
        (r#"{"proto":2,"no_op":1}"#.into(), "bad_request"),
        (r#"{"proto":2,"op":"nosuch"}"#.into(), "unknown_op"),
        (r#"{"op":"nosuch"}"#.into(), "unknown_op"),
        // malformed fields / parameters
        (r#"{"proto":2,"op":"dist","x":[1],"y":[1]}"#.into(), "bad_request"),
        (
            r#"{"proto":2,"op":"dist","measure":{"kind":"zzz"},"x":[1],"y":[1]}"#.into(),
            "bad_request",
        ),
        (
            r#"{"proto":2,"op":"dist","measure":{"kind":"krdtw","nu":-1},"x":[1],"y":[1]}"#.into(),
            "bad_request",
        ),
        (
            r#"{"proto":2,"op":"dist","measure":{"kind":"dtw"},"x":["a"],"y":[1]}"#.into(),
            "bad_request",
        ),
        (r#"{"op":"register_grid"}"#.into(), "bad_request"),
        (r#"{"op":"spdtw"}"#.into(), "bad_request"),
        // non-finite series values: bad_input on both protocols
        (
            r#"{"proto":2,"op":"dist","measure":{"kind":"dtw"},"x":[1e999],"y":[1]}"#.into(),
            "bad_input",
        ),
        (
            format!(r#"{{"op":"search","index":{idx},"k":1,"x":[1e999,0,0]}}"#),
            "bad_input",
        ),
        (
            format!(r#"{{"op":"batch_search","index":{idx},"k":1,"xs":[[-1e999,0,0]]}}"#),
            "bad_input",
        ),
        (
            r#"{"op":"register_index","series":[[1e999,0],[0,0]]}"#.into(),
            "bad_input",
        ),
        // unequal lengths for an equal-length measure: bad_input
        (
            r#"{"proto":2,"op":"kernel","measure":{"kind":"kga","nu":1},"x":[1,2],"y":[1,2,3]}"#
                .into(),
            "bad_input",
        ),
        // unknown keys: not_found
        (r#"{"op":"spdtw","grid":99,"x":[1],"y":[1]}"#.into(), "not_found"),
        (r#"{"op":"search","index":99,"k":1,"x":[0,0,0]}"#.into(), "not_found"),
        (
            r#"{"proto":2,"op":"dist","measure":42,"x":[1],"y":[1]}"#.into(),
            "not_found",
        ),
        (
            r#"{"proto":2,"op":"dist","measure":{"kind":"spdtw","grid":{"kind":"registered","key":9}},"x":[1],"y":[1]}"#
                .into(),
            "not_found",
        ),
    ];
    for (req, want_code) in cases {
        let r = call(&mut client, &req);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{req}");
        assert_eq!(r.req_str("code").unwrap(), want_code, "{req}");
        assert!(r.get("error").is_some(), "{req}");
    }

    // the connection survived every failure
    let r = call(&mut client, r#"{"op":"ping"}"#);
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
    server.stop();
}

/// Transport-free malformed-envelope matrix through
/// `server::dispatch_line` — the exact entry the `fuzz_wire` target
/// drives.  No sockets, so this is part of the Miri CI subset, and the
/// last rows pin the two fuzz findings as deterministic regressions:
/// unbounded JSON parse recursion (now capped at
/// `MAX_PARSE_DEPTH`) and unbounded v1 `register_grid`
/// materialization (now routed through `GridSpec::validate`).
#[test]
fn dispatch_line_matrix_returns_stable_codes_without_sockets() {
    use spdtw::coordinator::server::dispatch_line;
    let coord = Coordinator::start(CoordinatorConfig::default(), None).unwrap();

    let deep_array = format!("{}1{}", "[".repeat(4096), "]".repeat(4096));
    let deep_request = format!(r#"{{"op":"ping","junk":{deep_array}}}"#);
    let huge_grid = r#"{"op":"register_grid","t":1000000000}"#.to_string();

    let cases: Vec<(String, &str)> = vec![
        // truncated / not-JSON envelopes
        ("".into(), "bad_json"),
        ("{".into(), "bad_json"),
        (r#"{"op":"ping"#.into(), "bad_json"),
        (r#"{"op":"ping",}"#.into(), "bad_json"),
        ("not json at all".into(), "bad_json"),
        // wrong-type fields
        (r#"{"op":42}"#.into(), "bad_request"),
        (r#"{"op":"register_grid","t":"wide"}"#.into(), "bad_request"),
        (r#"{"op":"register_grid","t":-4}"#.into(), "bad_request"),
        (
            r#"{"op":"register_index","band":1,"series":"rows"}"#.into(),
            "bad_request",
        ),
        (
            r#"{"proto":2,"op":"search","index":0,"k":"one","x":[0]}"#.into(),
            "bad_request",
        ),
        (r#"{"proto":[2],"op":"ping"}"#.into(), "unsupported_proto"),
        // fuzz finding #1: hostile nesting must be a clean bad_json,
        // not a parser stack overflow
        (deep_request, "bad_json"),
        // fuzz finding #2: an oversized grid request must be refused by
        // `GridSpec::validate`, not materialize O(t²) cells
        (huge_grid, "bad_request"),
        // streaming op family: every malformed class answers the same
        // typed code as its batch counterpart, without a session or an
        // index ever being built.  Parse order is part of the contract:
        // envelope shape (bad_request) before value domain (bad_input)
        // before key resolution (not_found).
        (r#"{"op":"stream_open"}"#.into(), "bad_request"),
        (r#"{"op":"stream_open","index":"zero"}"#.into(), "bad_request"),
        (r#"{"op":"stream_open","index":99,"k":1}"#.into(), "not_found"),
        (r#"{"op":"stream_open","index":0,"rws":7}"#.into(), "bad_request"),
        (
            r#"{"op":"stream_open","index":0,"rws":{"d":"wide"}}"#.into(),
            "bad_request",
        ),
        (
            r#"{"op":"stream_open","index":0,"idle_timeout_ms":-5}"#.into(),
            "bad_request",
        ),
        (r#"{"op":"stream_push","stream":0}"#.into(), "bad_request"),
        (
            r#"{"op":"stream_push","stream":0,"values":[1,"x"]}"#.into(),
            "bad_request",
        ),
        (
            r#"{"op":"stream_push","stream":0,"values":[1e999]}"#.into(),
            "bad_input",
        ),
        (
            r#"{"op":"stream_push","stream":99,"values":[1]}"#.into(),
            "not_found",
        ),
        (r#"{"op":"stream_matches"}"#.into(), "bad_request"),
        (r#"{"op":"stream_matches","stream":7}"#.into(), "not_found"),
        (r#"{"op":"stream_close","stream":7}"#.into(), "not_found"),
    ];
    for (line, want_code) in cases {
        let r = dispatch_line(&line, &coord);
        assert_eq!(
            r.get("ok"),
            Some(&Json::Bool(false)),
            "{:.60}",
            line.as_str()
        );
        assert_eq!(r.req_str("code").unwrap(), want_code, "{:.60}", line.as_str());
        assert!(r.get("error").is_some());
    }

    // sanity: the same entry point still serves a healthy request
    let ok = dispatch_line(r#"{"op":"ping"}"#, &coord);
    assert_eq!(ok.get("pong"), Some(&Json::Bool(true)));
}
