//! End-to-end wire tests for the `stream_*` op family: an exact
//! session over real TCP must answer every completed window
//! bit-identically to the batch `search` op (neighbors AND prune
//! counters), an `rws` session must be flagged `approx` and report its
//! measured recall, idle sessions must be swept on the next open, and a
//! `deadline_ms` expiring mid-push must keep the already-ingested
//! prefix with the session still serviceable.

use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::util::json::Json;

fn start() -> (Server, Client) {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let server = Server::start(coord, "127.0.0.1:0").unwrap();
    let client = Client::connect(&server.addr).unwrap();
    (server, client)
}

fn call(client: &mut Client, req: &str) -> Json {
    client.call(&Json::parse(req).unwrap()).unwrap()
}

/// Register the shared 4-series corpus and return its index key.
fn register(client: &mut Client) -> usize {
    let r = call(
        client,
        concat!(
            r#"{"op":"register_index","band":1,"#,
            r#""series":[[0,0,0,0],[5,5,5,5],[1,2,3,4],[4,3,2,1]],"#,
            r#""labels":[0,1,0,1]}"#
        ),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    r.req_usize("index").unwrap()
}

/// Assert the `stream_matches` neighbor list equals the batch `search`
/// reply over the same window — distances bitwise, indexes exactly,
/// and (for the exact path, where the visit order is identical) the
/// prune counters exactly.  The RWS path refines candidates in
/// embedding order, so its counters legitimately differ even when its
/// answers are exact — `check_stats: false` skips only that part.
fn assert_matches_batch(
    client: &mut Client,
    matches: &Json,
    idx: usize,
    window: &str,
    k: usize,
    check_stats: bool,
) {
    let want = call(
        client,
        &format!(r#"{{"op":"search","index":{idx},"k":{k},"x":{window}}}"#),
    );
    let got_ns = matches.req_arr("neighbors").unwrap();
    let want_ns = want.req_arr("neighbors").unwrap();
    assert_eq!(got_ns.len(), want_ns.len(), "window {window}");
    for (g, w) in got_ns.iter().zip(want_ns) {
        assert_eq!(
            g.req_f64("dist").unwrap().to_bits(),
            w.req_f64("dist").unwrap().to_bits(),
            "window {window}"
        );
        assert_eq!(g.req_usize("idx").unwrap(), w.req_usize("idx").unwrap());
        assert_eq!(g.req_usize("label").unwrap(), w.req_usize("label").unwrap());
    }
    if check_stats {
        for field in ["pruned", "full_evals", "dp_cells"] {
            assert_eq!(
                matches.req_f64(field).unwrap(),
                want.req_f64(field).unwrap(),
                "prune counter {field} for window {window}"
            );
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn stream_exact_session_matches_search_op_bitwise() {
    let (mut server, mut client) = start();
    let idx = register(&mut client);

    let r = call(&mut client, &format!(r#"{{"op":"stream_open","index":{idx},"k":2}}"#));
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.req_usize("t").unwrap(), 4);
    assert_eq!(r.get("approx"), Some(&Json::Bool(false)));
    let s = r.req_usize("stream").unwrap();

    // three samples: no full window yet
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{s},"values":[0,0,0]}}"#),
    );
    assert_eq!(r.req_usize("pushed").unwrap(), 3);
    assert_eq!(r.req_usize("windows").unwrap(), 0);
    assert_eq!(r.get("ready"), Some(&Json::Bool(false)));
    let m = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{s}}}"#));
    assert_eq!(m.get("ready"), Some(&Json::Bool(false)));
    assert_eq!(m.req_usize("samples").unwrap(), 3);
    assert!(m.get("neighbors").is_none(), "no window yet: {m:?}");

    // fourth sample completes window [0,0,0,0]
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{s},"values":[0]}}"#),
    );
    assert_eq!(r.req_usize("windows").unwrap(), 1);
    assert_eq!(r.get("ready"), Some(&Json::Bool(true)));
    let m = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{s}}}"#));
    assert_eq!(m.get("approx"), Some(&Json::Bool(false)));
    assert_eq!(m.req_usize("window_start").unwrap(), 0);
    assert!(m.get("recall").is_none(), "exact path never reports recall");
    assert_matches_batch(&mut client, &m, idx, "[0,0,0,0]", 2, true);

    // two more samples slide two more windows; the report is the latest
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{s},"values":[9,9]}}"#),
    );
    assert_eq!(r.req_usize("windows").unwrap(), 2);
    let m = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{s}}}"#));
    assert_eq!(m.req_usize("samples").unwrap(), 6);
    assert_eq!(m.req_usize("windows").unwrap(), 3);
    assert_eq!(m.req_usize("window_start").unwrap(), 2);
    assert_matches_batch(&mut client, &m, idx, "[0,0,9,9]", 2, true);

    // close returns the session totals; the key is dead afterwards
    let r = call(&mut client, &format!(r#"{{"op":"stream_close","stream":{s}}}"#));
    assert_eq!(r.get("closed"), Some(&Json::Bool(true)));
    assert_eq!(r.req_usize("samples").unwrap(), 6);
    assert_eq!(r.req_usize("windows").unwrap(), 3);
    assert!(r.get("recall_at_k").is_none(), "exact session: {r:?}");
    let r = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{s}}}"#));
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.req_str("code").unwrap(), "not_found");

    let m = call(&mut client, r#"{"op":"metrics"}"#);
    assert_eq!(m.req_f64("streams_opened").unwrap(), 1.0);
    assert_eq!(m.req_f64("streams_closed").unwrap(), 1.0);
    assert_eq!(m.req_f64("stream_samples").unwrap(), 6.0);
    assert_eq!(m.req_f64("stream_windows").unwrap(), 3.0);

    server.stop();
}

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn stream_rws_session_is_flagged_and_reports_recall() {
    let (mut server, mut client) = start();
    let idx = register(&mut client);

    // candidate budget == corpus size: the pre-filter refines every
    // series through the exact cascade, so recall@k must measure 1.0
    let r = call(
        &mut client,
        &format!(
            r#"{{"op":"stream_open","index":{idx},"k":2,"rws":{{"d":2,"candidates":4,"audit_every":1}}}}"#
        ),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("approx"), Some(&Json::Bool(true)), "rws is never silent");
    let s = r.req_usize("stream").unwrap();

    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{s},"values":[1,2,3,4,4]}}"#),
    );
    assert_eq!(r.req_usize("windows").unwrap(), 2);
    let m = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{s}}}"#));
    assert_eq!(m.get("approx"), Some(&Json::Bool(true)));
    assert_eq!(m.req_f64("recall").unwrap(), 1.0, "audited window: {m:?}");
    assert_eq!(m.req_f64("recall_at_k").unwrap(), 1.0);
    // full budget means the answers themselves are the exact ones
    assert_matches_batch(&mut client, &m, idx, "[2,3,4,4]", 2, false);

    let r = call(&mut client, &format!(r#"{{"op":"stream_close","stream":{s}}}"#));
    assert_eq!(r.req_f64("recall_at_k").unwrap(), 1.0, "{r:?}");
    server.stop();
}

#[test]
#[cfg_attr(miri, ignore = "opens TCP sockets; dispatch_line covers the protocol under Miri")]
fn stream_idle_eviction_and_mid_push_deadline_keep_service_consistent() {
    let (mut server, mut client) = start();
    let idx = register(&mut client);

    // a zero idle timeout expires immediately; the next open sweeps it
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_open","index":{idx},"k":1,"idle_timeout_ms":0}}"#),
    );
    let dead = r.req_usize("stream").unwrap();
    let r = call(&mut client, &format!(r#"{{"op":"stream_open","index":{idx},"k":1}}"#));
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    let live = r.req_usize("stream").unwrap();
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{dead},"values":[1]}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.req_str("code").unwrap(), "not_found");
    let m = call(&mut client, r#"{"op":"metrics"}"#);
    assert!(m.req_f64("streams_evicted").unwrap() >= 1.0);

    // a 1ms deadline on a very large push expires mid-loop: the reply
    // is the typed code, the ingested prefix is kept, and the session
    // keeps serving
    let mut big = String::from("[");
    for i in 0..100_000 {
        if i > 0 {
            big.push(',');
        }
        big.push('1');
    }
    big.push(']');
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{live},"values":{big},"deadline_ms":1}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r:?}");
    assert_eq!(r.req_str("code").unwrap(), "deadline_exceeded");
    let m = call(&mut client, &format!(r#"{{"op":"stream_matches","stream":{live}}}"#));
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "session survives: {m:?}");
    assert!(
        m.req_usize("samples").unwrap() < 100_000,
        "deadline must stop the loop early: {m:?}"
    );

    // an undeadlined push still lands and completes windows
    let r = call(
        &mut client,
        &format!(r#"{{"op":"stream_push","stream":{live},"values":[1,2,3,4]}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("ready"), Some(&Json::Bool(true)));
    server.stop();
}
