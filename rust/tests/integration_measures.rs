//! Cross-measure integration: the paper's qualitative claims verified on
//! the synthetic archive (elasticity helps on warped data, sparsification
//! preserves accuracy while cutting cells, CORR == Ed, etc).

use spdtw::classify::gram::{cross_gram, gram_1nn_error};
use spdtw::classify::nn::classify_1nn;
use spdtw::data::synthetic;
use spdtw::measures::corr::CorrDist;
use spdtw::measures::dtw::Dtw;
use spdtw::measures::euclidean::Euclidean;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::sakoe_chiba::SakoeChibaDtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::sparse::learn::learn_occupancy_grid;

const THREADS: usize = 8;

#[test]
fn dtw_beats_euclid_on_cbf() {
    // CBF is the canonical time-warped dataset: elastic matching must
    // win (this is the premise of the whole paper).
    let ds = synthetic::generate_scaled("CBF", 42, 24, 60).unwrap();
    let ed = classify_1nn(&Euclidean, &ds.train, &ds.test, THREADS).error_rate;
    let dtw = classify_1nn(&Dtw, &ds.train, &ds.test, THREADS).error_rate;
    assert!(
        dtw <= ed,
        "DTW ({dtw}) should not lose to Ed ({ed}) on warped data"
    );
}

#[test]
fn corr_identical_to_ed_on_archive() {
    // Appendix A: z-normalized => identical 1-NN decisions.
    for name in ["CBF", "Gun-Point", "Wine"] {
        let ds = synthetic::generate_scaled(name, 7, 16, 24).unwrap();
        let ed = classify_1nn(&Euclidean, &ds.train, &ds.test, THREADS).error_rate;
        let corr = classify_1nn(&CorrDist, &ds.train, &ds.test, THREADS).error_rate;
        assert_eq!(ed, corr, "{name}");
    }
}

#[test]
fn spdtw_accuracy_close_to_dtw_with_far_fewer_cells() {
    // The headline claim: big speed-up (fewer visited cells) without
    // losing accuracy.
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 36, 48).unwrap();
    let t = ds.series_len();
    let grid = learn_occupancy_grid(&ds.train, THREADS);
    let loc = grid.threshold(5.0).to_loc(1.0);
    let nnz = loc.nnz() as f64;
    let full = (t * t) as f64;
    assert!(
        nnz < 0.6 * full,
        "sparsification too weak: {nnz} of {full} cells"
    );
    let sp = SpDtw::new(loc);
    let e_sp = classify_1nn(&sp, &ds.train, &ds.test, THREADS).error_rate;
    let e_dtw = classify_1nn(&Dtw, &ds.train, &ds.test, THREADS).error_rate;
    assert!(
        e_sp <= e_dtw + 0.12,
        "SP-DTW error {e_sp} much worse than DTW {e_dtw}"
    );
}

#[test]
fn spkrdtw_matches_krdtw_accuracy_on_sparse_grid() {
    let ds = synthetic::generate_scaled("CBF", 11, 18, 36).unwrap();
    let grid = learn_occupancy_grid(&ds.train, THREADS);
    let loc = grid.threshold(0.0).to_loc_mask();
    let nu = 0.1;
    let full = cross_gram(&Krdtw::new(nu), &ds.test, &ds.train, THREADS);
    let e_full = gram_1nn_error(&full, &ds.test, &ds.train);
    let sparse = cross_gram(&SpKrdtw::new(loc, nu), &ds.test, &ds.train, THREADS);
    let e_sparse = gram_1nn_error(&sparse, &ds.test, &ds.train);
    assert!(
        e_sparse <= e_full + 0.12,
        "SP-Krdtw {e_sparse} vs Krdtw {e_full}"
    );
}

#[test]
fn learned_grid_beats_equal_budget_corridor_on_shifted_data() {
    // The paper's key comparison (Tables II/III): a learned, asymmetric
    // search space outperforms a symmetric corridor of similar size on
    // data whose warping is structured.  CBF bumps shift right, so the
    // occupancy mass is off-diagonal in a structured way.
    let ds = synthetic::generate_scaled("CBF", 13, 30, 90).unwrap();
    let t = ds.series_len();
    let grid = learn_occupancy_grid(&ds.train, THREADS);
    let loc = grid.threshold(1.0).to_loc(1.0);
    let nnz = loc.nnz();
    // corridor with the same cell budget
    let band = (((nnz as f64) / t as f64 - 1.0) / 2.0).round().max(0.0) as usize;
    let sp = SpDtw::new(loc);
    let sc = SakoeChibaDtw::new(100.0 * band as f64 / t as f64);
    let e_sp = classify_1nn(&sp, &ds.train, &ds.test, THREADS);
    let e_sc = classify_1nn(&sc, &ds.train, &ds.test, THREADS);
    // same order of visited cells...
    let ratio = e_sp.visited_cells as f64 / e_sc.visited_cells.max(1) as f64;
    assert!(ratio < 2.0, "cell budgets differ too much: {ratio}");
    // ...and the learned grid should not be notably worse
    assert!(
        e_sp.error_rate <= e_sc.error_rate + 0.10,
        "SP-DTW {} vs DTW_sc {}",
        e_sp.error_rate,
        e_sc.error_rate
    );
}

#[test]
fn gamma_zero_spdtw_on_full_grid_equals_dtw_classification() {
    let ds = synthetic::generate_scaled("Gun-Point", 5, 14, 20).unwrap();
    let t = ds.series_len();
    let sp = SpDtw::new(spdtw::sparse::LocMatrix::full(t));
    let a = classify_1nn(&sp, &ds.train, &ds.test, THREADS);
    let b = classify_1nn(&Dtw, &ds.train, &ds.test, THREADS);
    assert_eq!(a.error_rate, b.error_rate);
    assert_eq!(a.visited_cells, b.visited_cells);
}

#[test]
fn speedup_grows_with_threshold_until_accuracy_collapses() {
    // ablation shape: cells monotonically drop with θ; error stays flat
    // then degrades — the trade-off Fig. 4 tunes.
    let ds = synthetic::generate_scaled("SyntheticControl", 21, 24, 30).unwrap();
    let grid = learn_occupancy_grid(&ds.train, THREADS);
    let mut last_cells = usize::MAX;
    for theta in [0.0, 1.0, 3.0, 8.0] {
        let loc = grid.threshold(theta).to_loc(1.0);
        assert!(loc.nnz() <= last_cells, "cells must shrink with theta");
        last_cells = loc.nnz();
    }
}
