//! Sharded-serving exactness suite: the front's fan-out/merge must be
//! *bit-identical* to a single-index engine over the union corpus — over
//! any random partition, any k (including k larger than every per-shard
//! count and the whole corpus), and under sentinel (`BIG + BIG`)
//! distance ties — and a dead shard must surface as the typed
//! `unavailable` partial-result error, never as a silently truncated
//! neighbor list.

use std::sync::Arc;

use spdtw::config::{CoordinatorConfig, ShardRole};
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::{LabeledSet, TimeSeries};
use spdtw::measures::BIG;
use spdtw::search::{Cascade, Index, Neighbor, SearchEngine};
use spdtw::shard::{
    merge_topk, FrontServer, ShardClientConfig, ShardCoordinator, ShardManifest, ShardNeighbor,
    ShardRegistration,
};
use spdtw::sparse::LocMatrix;
use spdtw::util::json::Json;
use spdtw::util::rng::Pcg64;

fn shard_cfg(shard_id: usize, shards_total: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        shard: Some(ShardRole {
            shard_id,
            shards_total,
        }),
        workers: 2,
        ..Default::default()
    }
}

/// Start `n` shard servers (each a full Coordinator + Server with a
/// `ShardRole`) on loopback ephemeral ports.
fn start_shards(n: usize) -> Vec<Server> {
    (0..n)
        .map(|i| {
            let coord = Arc::new(Coordinator::start(shard_cfg(i, n), None).unwrap());
            Server::start(coord, "127.0.0.1:0").unwrap()
        })
        .collect()
}

fn fleet_client_cfg(servers: &[Server], call_timeout_ms: u64) -> ShardClientConfig {
    ShardClientConfig {
        addrs: servers.iter().map(|s| s.addr.to_string()).collect(),
        connect_attempts: 2,
        backoff_base_ms: 5,
        backoff_cap_ms: 20,
        call_timeout_ms,
        // high threshold + no probe thread: these suites assert the
        // pre-breaker degradation contract deterministically
        breaker_threshold: 100,
        probe_interval_ms: 0,
        store: None,
    }
}

fn call(client: &mut Client, req: &str) -> Json {
    client.call(&Json::parse(req).unwrap()).unwrap()
}

fn random_series(rng: &mut Pcg64, n: usize, t: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..t).map(|_| rng.range(-2.0, 2.0)).collect())
        .collect()
}

fn labeled(series: &[Vec<f64>], labels: &[usize]) -> LabeledSet {
    LabeledSet::new(
        series
            .iter()
            .zip(labels)
            .map(|(v, &l)| TimeSeries::new(l, v.clone()))
            .collect(),
    )
}

/// Per-shard exact top-k from a local engine, remapped to global index
/// space through the partition — the in-process model of one fan-out
/// leg.
fn shard_list(
    engine: &SearchEngine,
    part: &[usize],
    query: &[f64],
    k: usize,
) -> Vec<ShardNeighbor> {
    engine
        .knn_values(query, k)
        .neighbors
        .iter()
        .map(|nb| ShardNeighbor {
            dist: nb.dist,
            label: nb.label,
            global_idx: part[nb.train_idx],
        })
        .collect()
}

fn assert_bit_identical(got: &[ShardNeighbor], want: &[Neighbor], ctx: &dyn std::fmt::Display) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "{ctx}");
        assert_eq!(g.global_idx, w.train_idx, "{ctx}");
        assert_eq!(g.label, w.label, "{ctx}");
    }
}

// ---------------------------------------------------------------------------
// in-process exactness properties (no TCP): merge == single engine
// ---------------------------------------------------------------------------

/// Property: for random corpora, random *arbitrary* partitions (uniform
/// shard choice per series, not just round-robin), random band widths
/// and random k — including k greater than every per-shard count and
/// greater than the whole corpus — merging per-shard exact top-k lists
/// reproduces the single-index engine's answer bit for bit.
#[test]
fn merged_topk_matches_single_engine_over_random_partitions() {
    let mut rng = Pcg64::new(0x5eed_0001);
    for case in 0..32 {
        let n = 3 + rng.below(28);
        let t = 4 + rng.below(12);
        let shards = 1 + rng.below(5);
        let band = 1 + rng.below(t);
        let k = 1 + rng.below(n + 2); // reaches k > n/shards and k > n
        let series = random_series(&mut rng, n, t);
        let labels: Vec<usize> = (0..n).map(|_| rng.below(3)).collect();

        let single = SearchEngine::new(
            Arc::new(Index::build(&labeled(&series, &labels), band, 2)),
            Cascade::default(),
        );

        // any partition works as long as each part keeps its global ids
        // ascending (parts are filled in increasing g, so they do)
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for g in 0..n {
            parts[rng.below(shards)].push(g);
        }
        let engines: Vec<(&Vec<usize>, SearchEngine)> = parts
            .iter()
            .filter(|p| !p.is_empty())
            .map(|part| {
                let sub_series: Vec<Vec<f64>> = part.iter().map(|&g| series[g].clone()).collect();
                let sub_labels: Vec<usize> = part.iter().map(|&g| labels[g]).collect();
                let idx = Index::build(&labeled(&sub_series, &sub_labels), band, 1);
                (part, SearchEngine::new(Arc::new(idx), Cascade::default()))
            })
            .collect();

        let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
        let lists: Vec<Vec<ShardNeighbor>> = engines
            .iter()
            .map(|(part, eng)| shard_list(eng, part, &query, k))
            .collect();
        let merged = merge_topk(lists, k);
        let want = single.knn_values(&query, k).neighbors;
        let ctx = format!("case {case}: n={n} t={t} shards={shards} band={band} k={k}");
        assert_bit_identical(&merged, &want, &ctx);
    }
}

/// Sentinel ties: a cornerless sparsity pattern makes *every* SP-DTW
/// distance the same finite sentinel (`BIG + BIG`), so the entire
/// ranking is decided by the index tie-break — the sharpest test of the
/// "per-shard order equals global order" precondition.
#[test]
fn sentinel_ties_merge_exactly() {
    let t = 4;
    let triples = vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)];
    let loc = Arc::new(LocMatrix::from_triples(t, triples));
    let mut rng = Pcg64::new(0x5eed_0002);
    let n = 9;
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|i| i % 2).collect();
    let single = SearchEngine::new(
        Arc::new(Index::build_spdtw(&labeled(&series, &labels), Arc::clone(&loc), 1)),
        Cascade::default(),
    );

    for shards in [2usize, 3] {
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for g in 0..n {
            parts[g % shards].push(g);
        }
        for k in [1usize, 4, n, n + 2] {
            let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
            let lists: Vec<Vec<ShardNeighbor>> = parts
                .iter()
                .map(|part| {
                    let sub_series: Vec<Vec<f64>> =
                        part.iter().map(|&g| series[g].clone()).collect();
                    let sub_labels: Vec<usize> = part.iter().map(|&g| labels[g]).collect();
                    let sub = labeled(&sub_series, &sub_labels);
                    let idx = Index::build_spdtw(&sub, Arc::clone(&loc), 1);
                    let eng = SearchEngine::new(Arc::new(idx), Cascade::default());
                    shard_list(&eng, part, &query, k)
                })
                .collect();
            let merged = merge_topk(lists, k);
            let want = single.knn_values(&query, k).neighbors;
            let ctx = format!("shards={shards} k={k}");
            assert_bit_identical(&merged, &want, &ctx);
            // every distance really is the unreachable-corner sentinel
            for m in &merged {
                assert_eq!(m.dist.to_bits(), (BIG + BIG).to_bits(), "{ctx}");
            }
            // ... so the ranking is exactly 0, 1, 2, … by global index
            let ids: Vec<usize> = merged.iter().map(|m| m.global_idx).collect();
            let expect: Vec<usize> = (0..k.min(n)).collect();
            assert_eq!(ids, expect, "{ctx}");
        }
    }
}

// ---------------------------------------------------------------------------
// TCP end-to-end: real shard servers + front over the wire
// ---------------------------------------------------------------------------

/// Two real shard servers, a connected front, a named registration: the
/// merged answers (library API *and* wire replies through a
/// `FrontServer`) are bit-identical to a single-index engine, the
/// partition is recorded in the shard manifest, and batch answers match
/// single answers query by query.
#[test]
fn tcp_fleet_matches_single_index_bit_for_bit() {
    let servers = start_shards(2);
    let store = std::env::temp_dir().join(format!("spdtw_shard_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);
    let mut cfg = fleet_client_cfg(&servers, 10_000);
    cfg.store = Some(store.clone());
    let sc = ShardCoordinator::connect(cfg).unwrap();
    assert_eq!(sc.shards_total(), 2);
    assert_eq!(sc.links_up(), vec![true, true]);

    let mut rng = Pcg64::new(0xfee1_d00d);
    let n = 11;
    let t = 8;
    let band = 2;
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
    let si = sc
        .register(&ShardRegistration {
            name: Some("fleet".to_string()),
            series: series.clone(),
            labels: labels.clone(),
            band: Some(band),
            measure: None,
        })
        .unwrap();
    assert_eq!(si.total, n);
    assert_eq!(si.per_shard_count.iter().sum::<usize>(), n);
    assert_eq!(sc.key_by_name("fleet"), Some(si.key));

    // the manifest recorded the split and both shards' content hashes
    let manifest = ShardManifest::load(&store).unwrap();
    assert_eq!(manifest.name, "fleet");
    assert_eq!(manifest.shards_total, 2);
    assert_eq!(manifest.total, n);
    assert_eq!(manifest.t, t);
    for (entry, count) in manifest.entries.iter().zip(&si.per_shard_count) {
        assert_eq!(entry.count, *count);
        assert!(entry.content_hash.is_some());
    }

    let single = SearchEngine::new(
        Arc::new(Index::build(&labeled(&series, &labels), band, 2)),
        Cascade::default(),
    );

    // single searches across the k regimes (k=7 > per-shard counts of
    // 6/5; k=n+3 > the whole corpus)
    let mut last_query = Vec::new();
    for k in [1usize, 3, 7, n + 3] {
        let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
        let got = sc.search(si.key, &query, k, None).unwrap();
        assert_eq!(got.shards_ok, 2);
        assert_eq!(got.shards_total, 2);
        let want = single.knn_values(&query, k).neighbors;
        let ctx = format!("tcp search k={k}");
        assert_bit_identical(&got.neighbors, &want, &ctx);
        last_query = query;
    }

    // batch: every query merged independently, all exact
    let queries: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..t).map(|_| rng.range(-2.0, 2.0)).collect())
        .collect();
    let outs = sc.batch_search(si.key, &queries, 4, None).unwrap();
    assert_eq!(outs.len(), queries.len());
    for (q, out) in queries.iter().zip(&outs) {
        let want = single.knn_values(q, 4).neighbors;
        assert_bit_identical(&out.neighbors, &want, &"tcp batch_search k=4");
    }

    // the same answer through the FrontServer wire protocol, with the
    // v2 id echo and the fan-out health fields on the reply
    let front = FrontServer::start(Arc::clone(&sc), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&front.addr).unwrap();
    let req = Json::obj(vec![
        ("proto", Json::num(2.0)),
        ("id", Json::num(7.0)),
        ("op", Json::str("search")),
        ("index", Json::str("fleet")),
        ("k", Json::num(3.0)),
        ("x", Json::arr(last_query.iter().copied().map(Json::num))),
    ]);
    let reply = client.call(&req).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    assert_eq!(reply.req_usize("id").unwrap(), 7);
    assert_eq!(reply.req_usize("shards_ok").unwrap(), 2);
    assert_eq!(reply.req_usize("shards_total").unwrap(), 2);
    let want = single.knn_values(&last_query, 3).neighbors;
    let ns = reply.req_arr("neighbors").unwrap();
    assert_eq!(ns.len(), want.len());
    for (j, w) in ns.iter().zip(&want) {
        // JSON emits the shortest round-trip form of every f64, so
        // bit-equality survives the wire
        assert_eq!(j.req_f64("dist").unwrap().to_bits(), w.dist.to_bits());
        assert_eq!(j.req_usize("idx").unwrap(), w.train_idx);
        assert_eq!(j.req_usize("label").unwrap(), w.label);
    }

    let snap = sc.metrics();
    assert!(snap.fanouts >= 6);
    assert_eq!(snap.partial_failures, 0);
    assert!(snap.merges >= 6);
    let _ = std::fs::remove_dir_all(&store);
}

/// Killing one shard mid-session degrades every fan-out to the typed
/// `ShardUnavailable` partial-result error — on the library API and as
/// a wire reply with `code: "unavailable"` plus `shards_ok` /
/// `shards_total` — instead of returning a truncated merge.
#[test]
fn killed_shard_yields_typed_partial_result_error() {
    let mut servers = start_shards(2);
    let sc = ShardCoordinator::connect(fleet_client_cfg(&servers, 2_000)).unwrap();

    let mut rng = Pcg64::new(0xdead_5eed);
    let t = 6;
    let series = random_series(&mut rng, 8, t);
    let labels = vec![0usize; 8];
    let si = sc
        .register(&ShardRegistration {
            name: None,
            series,
            labels,
            band: Some(1),
            measure: None,
        })
        .unwrap();
    let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
    assert_eq!(sc.search(si.key, &query, 2, None).unwrap().shards_ok, 2);

    // kill shard 1 the way an operator would: the TCP shutdown op, then
    // the process (here: the Server) goes away and the port closes
    let s1 = servers.pop().unwrap();
    let mut killer = Client::connect(&s1.addr).unwrap();
    let r = call(&mut killer, r#"{"op":"shutdown"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    drop(s1);

    let err = sc.search(si.key, &query, 2, None).unwrap_err();
    assert_eq!(err.code(), "unavailable");
    let shown = err.to_string();
    assert!(shown.contains("1/2 shards answered"), "{shown}");
    match &err {
        spdtw::Error::ShardUnavailable {
            shards_ok,
            shards_total,
            ..
        } => {
            assert_eq!(*shards_ok, 1);
            assert_eq!(*shards_total, 2);
        }
        other => panic!("expected ShardUnavailable, got {other:?}"),
    }

    // same degradation over the wire through the front
    let front = FrontServer::start(Arc::clone(&sc), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&front.addr).unwrap();
    let req = Json::obj(vec![
        ("proto", Json::num(2.0)),
        ("id", Json::num(9.0)),
        ("op", Json::str("search")),
        ("index", Json::num(si.key as f64)),
        ("k", Json::num(2.0)),
        ("x", Json::arr(query.iter().copied().map(Json::num))),
    ]);
    let reply = client.call(&req).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply:?}");
    assert_eq!(reply.req_usize("id").unwrap(), 9);
    assert_eq!(reply.req_str("code").unwrap(), "unavailable");
    assert_eq!(reply.req_usize("shards_ok").unwrap(), 1);
    assert_eq!(reply.req_usize("shards_total").unwrap(), 2);

    let snap = sc.metrics();
    assert!(snap.partial_failures >= 2, "{}", snap.report());
    assert!(snap.shards[1].errors >= 1);
    assert!(snap.shards[0].calls >= 2);
}

// ---------------------------------------------------------------------------
// registration guards: a shard can never silently hold the wrong slice
// ---------------------------------------------------------------------------

/// Satellite fix: `register_index` on a shard server rejects shard ids
/// outside the layout (plus mis-routes, named sharded registrations,
/// and non-increasing `global_ids`) with typed `bad_request` replies,
/// and `shard_search` guards its own shard id and the `global_ids`
/// requirement.
#[test]
fn shard_server_rejects_bad_sharded_registrations() {
    let coord = Arc::new(Coordinator::start(shard_cfg(0, 2), None).unwrap());
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let series = r#""series":[[0,0],[1,1]],"labels":[0,1]"#;

    for (req, needle) in [
        (
            format!(r#"{{"op":"register_index","shard":5,"global_ids":[0,2],{series}}}"#),
            "outside the layout",
        ),
        (
            format!(r#"{{"op":"register_index","shard":1,"global_ids":[0,2],{series}}}"#),
            "mis-routed",
        ),
        (
            format!(
                r#"{{"op":"register_index","shard":0,"name":"corpus","global_ids":[0,2],{series}}}"#
            ),
            "anonymous",
        ),
        (
            format!(r#"{{"op":"register_index","shard":0,"global_ids":[3,1],{series}}}"#),
            "strictly increasing",
        ),
        (
            format!(r#"{{"op":"register_index","shard":0,{series}}}"#),
            "requires 'global_ids'",
        ),
        (
            format!(r#"{{"op":"register_index","global_ids":[0,2],{series}}}"#),
            "requires 'shard'",
        ),
    ] {
        let r = call(&mut client, &req);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{req}");
        assert_eq!(r.req_str("code").unwrap(), "bad_request", "{req}");
        assert!(r.req_str("error").unwrap().contains(needle), "{req} -> {r:?}");
    }

    // a correct sharded registration succeeds and answers shard_search
    // in global index space (idx from global_ids, local_idx preserved)
    let r = call(
        &mut client,
        &format!(r#"{{"op":"register_index","shard":0,"global_ids":[0,2],{series}}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.req_usize("shard").unwrap(), 0);
    let key = r.req_usize("index").unwrap();

    let r = call(
        &mut client,
        &format!(r#"{{"op":"shard_search","shard":0,"index":{key},"k":1,"x":[1,1]}}"#),
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    let ns = r.req_arr("neighbors").unwrap();
    assert_eq!(ns[0].req_usize("idx").unwrap(), 2); // global, not local 1
    assert_eq!(ns[0].req_usize("local_idx").unwrap(), 1);
    assert_eq!(ns[0].req_f64("dist").unwrap(), 0.0);

    // shard_search guards: a mis-routed leg and a plain (unsharded)
    // index are both bad_request, never a wrong merge input
    let r = call(
        &mut client,
        &format!(r#"{{"op":"shard_search","shard":1,"index":{key},"k":1,"x":[1,1]}}"#),
    );
    assert_eq!(r.req_str("code").unwrap(), "bad_request");
    assert!(r.req_str("error").unwrap().contains("mis-routed"));

    let r = call(&mut client, &format!(r#"{{"op":"register_index",{series}}}"#));
    let plain = r.req_usize("index").unwrap();
    let r = call(
        &mut client,
        &format!(r#"{{"op":"shard_search","shard":0,"index":{plain},"k":1,"x":[1,1]}}"#),
    );
    assert_eq!(r.req_str("code").unwrap(), "bad_request");
    assert!(r.req_str("error").unwrap().contains("global_ids"));
    server.stop();
}

/// A plain (role-less) server refuses shard ops, and the front refuses
/// to adopt it — topology mistakes fail loudly at the boundary.
#[test]
fn plain_server_rejects_shard_ops_and_front_verifies_topology() {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();

    let r = call(
        &mut client,
        r#"{"op":"register_index","shard":0,"global_ids":[0],"series":[[0,0]]}"#,
    );
    assert_eq!(r.req_str("code").unwrap(), "bad_request");
    assert!(r.req_str("error").unwrap().contains("non-shard server"));

    let r = call(
        &mut client,
        r#"{"op":"shard_search","shard":0,"index":0,"k":1,"x":[0]}"#,
    );
    assert_eq!(r.req_str("code").unwrap(), "bad_request");
    assert!(r.req_str("error").unwrap().contains("non-shard server"));

    let addrs = vec![server.addr.to_string()];
    let err = ShardCoordinator::connect(ShardClientConfig::for_addrs(addrs)).unwrap_err();
    assert_eq!(err.code(), "bad_request");
    assert!(err.to_string().contains("not a shard server"), "{err}");
    server.stop();

    // a shard server whose role disagrees with the front's fleet size
    // is a topology mismatch, refused at connect time
    let shards = start_shards(2); // roles are "shard i of 2"
    let addrs = vec![shards[0].addr.to_string()];
    let err = ShardCoordinator::connect(ShardClientConfig::for_addrs(addrs)).unwrap_err();
    assert_eq!(err.code(), "bad_request");
    assert!(err.to_string().contains("topology mismatch"), "{err}");
}
