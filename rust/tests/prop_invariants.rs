//! Property-based invariants (in-tree harness, `util::prop`) over the
//! measures, the sparsification pipeline and the coordinator.

use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::Coordinator;
use spdtw::data::splits::from_pairs;
use spdtw::data::TimeSeries;
use spdtw::measures::dtw::{dtw_banded, dtw_with_path, is_valid_path};
use spdtw::measures::euclidean::Euclidean;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::lb_keogh::envelope;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::search::early::{dtw_banded_ea, spdtw_ea};
use spdtw::search::lower_bounds::{lb_keogh_sum, lb_kim};
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::sparse::{LocMatrix, OccupancyGrid};
use spdtw::util::prop::{forall_pairs, forall_usizes, forall_vec, PropConfig};

#[test]
fn prop_dtw_nonnegative_symmetric_zero_on_self() {
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 40, 5.0, |x, y| {
        let d = dtw_banded(x, y, usize::MAX).value;
        let d2 = dtw_banded(y, x, usize::MAX).value;
        let dself = dtw_banded(x, x, usize::MAX).value;
        d >= 0.0 && (d - d2).abs() < 1e-9 && dself.abs() < 1e-12
    });
}

#[test]
fn prop_banded_cost_decreases_with_band() {
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 4, 32, 3.0, |x, y| {
        let narrow = dtw_banded(x, y, 1).value;
        let mid = dtw_banded(x, y, 4).value;
        let full = dtw_banded(x, y, usize::MAX).value;
        narrow + 1e-12 >= mid && mid + 1e-12 >= full
    });
}

#[test]
fn prop_backtracked_path_always_valid() {
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 28, 4.0, |x, y| {
        let (d, path) = dtw_with_path(x, y);
        let cost: f64 = path
            .iter()
            .map(|&(i, j)| (x[i] - y[j]) * (x[i] - y[j]))
            .sum();
        is_valid_path(&path, x.len(), y.len()) && (cost - d.value).abs() < 1e-9
    });
}

#[test]
fn prop_spdtw_full_grid_equals_dtw() {
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 24, 4.0, |x, y| {
        let sp = SpDtw::new(LocMatrix::full(x.len()));
        let a = sp.eval(x, y).value;
        let b = dtw_banded(x, y, usize::MAX).value;
        (a - b).abs() < 1e-9
    });
}

#[test]
fn prop_krdtw_normalized_kernel_bounded() {
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 24, 3.0, |x, y| {
        let k = Krdtw::new(1.0);
        let kxy = k.log_kernel(x, y).value;
        let kxx = k.log_kernel(x, x).value;
        let kyy = k.log_kernel(y, y).value;
        // normalized kernel in (0, 1]
        kxy - 0.5 * (kxx + kyy) <= 1e-9
    });
}

#[test]
fn prop_occupancy_path_cells_all_present_prethreshold() {
    let cfg = PropConfig::default();
    forall_vec(&cfg, 4, 24, 2.0, |x| {
        let y: Vec<f64> = x.iter().rev().cloned().collect();
        let (_, path) = dtw_with_path(x, &y);
        let mut grid = OccupancyGrid::new(x.len());
        grid.add_path(&path);
        let loc = grid.threshold(0.0).to_loc(1.0);
        path.iter().all(|&(i, j)| loc.get(i, j).is_some())
    });
}

#[test]
fn prop_threshold_monotone_shrinks_support() {
    let cfg = PropConfig::default();
    forall_usizes(&cfg, &[(2, 16), (1, 9)], |vals| {
        let (t, npaths) = (vals[0], vals[1]);
        let mut grid = OccupancyGrid::new(t);
        // deterministic pseudo-paths: staircases with different offsets
        for p in 0..npaths {
            let path: Vec<(usize, usize)> = (0..t)
                .map(|i| (i, ((i + p) % t).min(t - 1)))
                .collect();
            // make monotone: clamp to sorted columns
            let mut mono = Vec::new();
            let mut maxj = 0;
            for (i, j) in path {
                maxj = maxj.max(j.min(i + 1));
                mono.push((i, maxj.min(t - 1)));
            }
            grid.add_path(&mono);
        }
        let mut last = usize::MAX;
        for theta in 0..4 {
            let n = grid.threshold(theta as f64).nnz();
            if n > last {
                return false;
            }
            last = n;
        }
        true
    });
}

#[test]
fn prop_cascade_lower_bound_chain() {
    // THE cascade invariant: LB_Kim <= LB_Keogh <= banded DTW for every
    // radius — a candidate pruned by a cheap stage can never have
    // survived a more expensive one.
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 36, 4.0, |x, y| {
        [1usize, 3, 8, x.len().saturating_sub(1).max(1)]
            .into_iter()
            .all(|r| {
                let (u, l) = envelope(y, r);
                let kim = lb_kim(x, &u, &l);
                let keogh = lb_keogh_sum(x, &u, &l);
                let d = dtw_banded(x, y, r).value;
                kim <= keogh + 1e-12 && keogh <= d + 1e-9
            })
    });
}

#[test]
fn prop_cascade_lb_bounds_spdtw_on_learned_weights() {
    // SP-DTW with weights >= 1 restricted to cells within the grid's
    // off-diagonal reach is also bounded below by the cascade.
    let cfg = PropConfig { cases: 24, ..Default::default() };
    forall_pairs(&cfg, 4, 24, 3.0, |x, y| {
        let t = x.len();
        let band = (t / 4).max(1);
        let mut triples = Vec::new();
        for i in 0..t {
            for j in i.saturating_sub(band)..=(i + band).min(t - 1) {
                // deterministic pseudo-learned weights, all >= 1
                let w = 1.0 + ((i * 7 + j * 13) % 5) as f64 * 0.5;
                triples.push((i, j, w));
            }
        }
        let loc = LocMatrix::from_triples(t, triples);
        let r = loc.max_band_offset();
        let (u, l) = envelope(y, r);
        let kim = lb_kim(x, &u, &l);
        let keogh = lb_keogh_sum(x, &u, &l);
        let d = SpDtw::new(loc).eval(x, y).value;
        kim <= keogh + 1e-12 && keogh <= d + 1e-9
    });
}

#[test]
fn prop_early_abandon_exact_when_completed() {
    // EA kernels must return the bit-exact exhaustive value whenever
    // they complete, and only abandon when the true value >= ub.
    let cfg = PropConfig::default();
    forall_pairs(&cfg, 2, 30, 4.0, |x, y| {
        let t = x.len();
        let band = (t / 3).max(1);
        let exact = dtw_banded(x, y, band).value;
        let loc = LocMatrix::corridor(t, band);
        let sp_exact = SpDtw::new(loc.clone()).eval(x, y).value;
        [0.0, 0.3, 0.7, 1.0, 1.5]
            .into_iter()
            .all(|frac| {
                let ub = frac * exact;
                let ea = dtw_banded_ea(x, y, band, ub);
                let dtw_ok = match ea.value {
                    Some(v) => v.to_bits() == exact.to_bits(),
                    None => exact >= ub,
                };
                let ub_sp = frac * sp_exact;
                let ea_sp = spdtw_ea(&loc, x, y, ub_sp);
                let sp_ok = match ea_sp.value {
                    Some(v) => v.to_bits() == sp_exact.to_bits(),
                    None => sp_exact >= ub_sp,
                };
                dtw_ok && sp_ok
            })
    });
}

#[test]
fn prop_search_engine_matches_bruteforce_knn() {
    // End-to-end cascade exactness: engine top-k == stable-sorted
    // brute-force top-k, bit for bit, on random little train sets.
    let cfg = PropConfig { cases: 24, ..Default::default() };
    forall_usizes(&cfg, &[(3, 10), (4, 16), (1, 3)], |vals| {
        let (n, t, k) = (vals[0], vals[1], vals[2].min(vals[0]));
        let mk = |s: usize| -> Vec<f64> {
            (0..t)
                .map(|i| (((s * 31 + i * 17) % 23) as f64 * 0.37).sin() * 2.0)
                .collect()
        };
        let train = from_pairs((0..n).map(|s| (s % 3, mk(s))).collect());
        let band = (t / 3).max(1);
        let index = Arc::new(Index::build(&train, band, 1));
        let engine = SearchEngine::new(Arc::clone(&index), Cascade::default());
        let q = mk(n + 1);
        let got = engine.knn_values(&q, k);
        let mut want: Vec<(f64, usize)> = (0..n)
            .map(|j| (dtw_banded(&q, &index.series[j], band).value, j))
            .collect();
        want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        want.truncate(k);
        got.neighbors.len() == want.len()
            && got
                .neighbors
                .iter()
                .zip(&want)
                .all(|(g, (wd, wj))| g.dist.to_bits() == wd.to_bits() && g.train_idx == *wj)
    });
}

#[test]
fn prop_coordinator_answers_every_job_exactly_once() {
    // THE coordinator invariant: N submissions -> N completions, values
    // matching the direct evaluation, regardless of worker/batch config.
    let cfg = PropConfig { cases: 8, ..Default::default() };
    forall_usizes(&cfg, &[(1, 4), (1, 50), (4, 24)], |vals| {
        let (workers, njobs, t) = (vals[0], vals[1], vals[2]);
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers,
                queue_cap: 4,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let key = coord.register_grid(LocMatrix::corridor(t, 2)).unwrap();
        let mk = |i: usize| {
            TimeSeries::new(0, (0..t).map(|k| ((i * 7 + k) % 13) as f64).collect())
        };
        let tickets: Vec<_> = (0..njobs)
            .map(|i| coord.submit_spdtw(key, &mk(i), &mk(i + 1)).unwrap())
            .collect();
        let direct = SpDtw::new(LocMatrix::corridor(t, 2));
        let mut ok = true;
        for (i, ticket) in tickets.into_iter().enumerate() {
            let r = ticket.wait().unwrap();
            let want = direct.dist(&mk(i), &mk(i + 1)).value;
            ok &= (r.value - want).abs() < 1e-9;
        }
        coord.wait_native_idle();
        let snap = coord.metrics();
        ok && snap.completed == njobs as u64 && snap.submitted == njobs as u64
    });
}

#[test]
fn prop_native_submissions_under_churn() {
    // failure-injection-ish: interleave submissions from several threads
    // while the coordinator is running; all must resolve.
    let coord = Arc::new(
        Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                queue_cap: 2,
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for th in 0..4 {
        let c = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut acc = 0usize;
            for i in 0..50 {
                let x = TimeSeries::new(0, vec![(th + i) as f64; 8]);
                let y = TimeSeries::new(0, vec![i as f64; 8]);
                let t = c.submit_native(Arc::new(Euclidean), &x, &y);
                let r = t.wait().unwrap();
                if r.value.is_finite() {
                    acc += 1;
                }
            }
            acc
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 200);
    assert_eq!(coord.metrics().completed, 200);
}
