//! Loom model-checking of the concurrent-epoch compute pool.
//!
//! This suite compiles to **nothing** in normal builds: it requires
//! `RUSTFLAGS="--cfg loom"` plus a dev-dependency on `loom` (added
//! ephemerally by the CI `loom-model` job — see
//! `.github/workflows/ci.yml` — so the shipped manifest stays
//! dependency-free; locally: `cargo add --dev loom && RUSTFLAGS="--cfg
//! loom" cargo test --release --test loom_pool`).
//!
//! Under `--cfg loom`, `pool::sync` swaps every primitive the scheduler
//! synchronizes through (mutex, both condvars, the claim counter and
//! panic flag atomics, the output-slot cells) for loom's model-checked
//! versions, and each `loom::model` block below *enumerates* the
//! thread interleavings of one scheduler scenario instead of sampling
//! them like `tests/stress_pool.rs`.  Loom fails a model if any
//! explored schedule deadlocks, leaks a thread, violates an assertion,
//! or touches an `UnsafeCell` from two threads without a
//! happens-before edge — the last being precisely the "disjoint slot
//! writes are race-free" claim the `// SAFETY:` comments in
//! `pool/mod.rs` make in prose.
//!
//! Scenarios (mirroring the ISSUE-7 checklist):
//! 1. epoch claim + latch completion (worker joins, submitter waits)
//! 2. two-epoch overlap from distinct submitters with least-served
//!    claiming by a shared worker
//! 3. submitter self-participation completing an epoch with no worker
//! 4. panic isolation: a panicked epoch aborts alone, pool survives
//! 5. disjoint-slot write safety under racing chunk claims
//! 6. shutdown wakes parked workers and joins every thread
//!
//! Every model ends in `ComputePool::shutdown()` — loom requires all
//! model threads to terminate, so thread-leak freedom is itself part of
//! each check.  `preemption_bound` caps exploration (sound for all bugs
//! requiring ≤ N preemptions; exhaustive small-scope checking in the
//! sense loom's docs describe).

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use loom::sync::Arc;
use spdtw::measures::workspace::DpWorkspace;
use spdtw::pool::ComputePool;

/// Bounded exploration: every schedule reachable with at most this many
/// forced preemptions is checked.  2–3 is the loom-recommended range;
/// raising it explodes state for the 3-thread models below.
const PREEMPTION_BOUND: usize = 3;

fn model(f: impl Fn() + Send + Sync + 'static) {
    let mut builder = loom::model::Builder::new();
    builder.preemption_bound = Some(PREEMPTION_BOUND);
    builder.check(f);
}

/// 1. The basic claim/latch protocol: one worker, one epoch with room
/// for two participants.  Every interleaving of (worker claims item |
/// submitter claims item | worker still parked) must produce the exact
/// in-order results and complete the latch.
#[test]
fn epoch_claim_and_latch_completion() {
    model(|| {
        let pool = ComputePool::start(1);
        let out = pool.run(2, 2, 1, &|i, _ws: &mut DpWorkspace| i * 10 + 1);
        assert_eq!(out, vec![1, 11]);
        pool.shutdown();
    });
}

/// 2. Two epochs live at once from distinct submitter threads, one
/// shared worker: exercises `pick`'s least-served selection (the worker
/// chooses between two claimable epochs, ties broken to the older id)
/// and proves the per-epoch latches never cross — each submitter gets
/// exactly its own epoch's results, under every schedule.
#[test]
fn two_epoch_overlap_least_served_claiming() {
    model(|| {
        let pool = ComputePool::start(1);
        let p2 = Arc::clone(&pool);
        let other = loom::thread::spawn(move || {
            p2.run(1, 2, 1, &|i, _ws: &mut DpWorkspace| i + 100)
        });
        let mine = pool.run(1, 2, 1, &|i, _ws: &mut DpWorkspace| i + 200);
        assert_eq!(mine, vec![200]);
        assert_eq!(other.join().unwrap(), vec![100]);
        pool.shutdown();
    });
}

/// 3. Submitter self-participation: with `threads = 1` the submitter is
/// the epoch's only permitted participant (`running == target` from
/// registration), so the epoch must complete even if the pool worker
/// never claims it — progress may not depend on worker availability.
#[test]
fn submitter_completes_epoch_without_workers() {
    model(|| {
        let pool = ComputePool::start(1);
        let out = pool.run(2, 1, 2, &|i, _ws: &mut DpWorkspace| i + 7);
        assert_eq!(out, vec![7, 8]);
        pool.shutdown();
    });
}

/// 4. Panic isolation: an epoch whose item panics aborts (submitter
/// sees "pool worker panicked" whether the worker or the submitter ran
/// the poisoned item — loom explores both), and the *same* pool then
/// serves a healthy epoch — no schedule may leave the scheduler wedged
/// or a latch incomplete.
#[test]
fn panic_isolation_pool_survives() {
    model(|| {
        let pool = ComputePool::start(1);
        let poisoned = catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, 2, 1, &|i, _ws: &mut DpWorkspace| {
                if i == 0 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(poisoned.is_err());
        let ok = pool.run(1, 2, 1, &|i, _ws: &mut DpWorkspace| i + 5);
        assert_eq!(ok, vec![5]);
        pool.shutdown();
    });
}

/// 5. Disjoint-slot write safety: worker and submitter race the atomic
/// chunk counter over three items (chunk = 2, so one participant takes
/// a two-item run).  Loom's instrumented `UnsafeCell` slots fail the
/// model if any schedule lets two threads touch one slot without a
/// happens-before edge, or lets the submitter read a slot that wasn't
/// published by the completion latch — the machine-checked version of
/// the `EpochSlots` SAFETY argument.
#[test]
fn disjoint_slot_writes_are_race_free() {
    model(|| {
        let pool = ComputePool::start(1);
        let out = pool.run(3, 2, 2, &|i, _ws: &mut DpWorkspace| i * 3);
        assert_eq!(out, vec![0, 3, 6]);
        pool.shutdown();
    });
}

/// 6. Shutdown on an idle pool: both workers are parked on `work_cv`
/// (or still starting up — loom explores both); `shutdown` must wake
/// every schedule's workers exactly once and join them — a lost wakeup
/// here is a hung process in the `std` build.
#[test]
fn shutdown_wakes_parked_workers() {
    model(|| {
        let pool = ComputePool::start(2);
        pool.shutdown();
    });
}
