//! Concurrent-epoch scheduler stress tests: ≥4 submitter threads
//! driving mixed-size `par_map_ws` epochs simultaneously must produce
//! bit-exact results (no epoch may ever observe another epoch's output
//! slots or claim counter), panics must stay contained to their own
//! epoch, and epochs from distinct threads must provably overlap —
//! the multi-client throughput contract behind
//! `Coordinator::submit_batch_search`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use spdtw::measures::dtw::dtw_banded;
use spdtw::pool::{self, par_map, par_map_ws};
use spdtw::search::early::dtw_banded_ea_into;
use spdtw::util::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
    (0..t).map(|_| rng.normal()).collect()
}

/// 6 threads × 8 rounds of mixed-size epochs, half cheap arithmetic and
/// half real DP kernels, all racing on the shared worker set.  Every
/// epoch's output must be bit-identical to its serial oracle: a single
/// leaked slot write or shared claim counter between epochs would show
/// up as a wrong length, a `None` slot panic, or a foreign value.
#[test]
fn concurrent_mixed_size_epochs_are_bit_exact() {
    let threads = 6;
    let rounds = 8;
    let handles: Vec<_> = (0..threads)
        .map(|tid| {
            thread::spawn(move || {
                let mut rng = Pcg64::new(0xabc0 + tid as u64);
                for round in 0..rounds {
                    // mixed sizes: every (thread, round) uses its own n
                    let n = 17 + 31 * tid + 13 * round;
                    if tid % 2 == 0 {
                        // arithmetic epoch: values encode (tid, round, i),
                        // so a foreign epoch's write is detectable
                        let want: Vec<f64> = (0..n)
                            .map(|i| 0.25 + (tid * 1_000_003 + round * 7919 + i) as f64)
                            .collect();
                        let got = par_map_ws(n, 4, 3, |i, ws| {
                            let (row, _) = ws.rows(4 + (i % 5), 0.25);
                            row[0] + (tid * 1_000_003 + round * 7919 + i) as f64
                        });
                        assert_eq!(got, want, "tid={tid} round={round}");
                    } else {
                        // DP epoch: banded DTW at per-item bands against
                        // the exhaustive serial oracle, bit-for-bit
                        let t = 12 + 2 * (round % 4);
                        let x = rand_vec(&mut rng, t);
                        let y = rand_vec(&mut rng, t);
                        let want: Vec<u64> = (0..n)
                            .map(|i| dtw_banded(&x, &y, 1 + (i % 7)).value.to_bits())
                            .collect();
                        let got = par_map_ws(n, 4, 1, |i, ws| {
                            dtw_banded_ea_into(ws, &x, &y, 1 + (i % 7), f64::INFINITY)
                                .value
                                .unwrap()
                                .to_bits()
                        });
                        assert_eq!(got, want, "tid={tid} round={round}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("stress thread panicked");
    }
}

/// Four submitters rendezvous *inside* their running epochs: each epoch
/// blocks until it has seen every other epoch start.  Under a global
/// submit lock only one epoch can run at a time, so this times out;
/// under the concurrent-epoch scheduler all four complete.
#[test]
fn four_submitters_epochs_all_overlap() {
    let flags: Arc<Vec<AtomicBool>> = Arc::new((0..4).map(|_| AtomicBool::new(false)).collect());
    let handles: Vec<_> = (0..4)
        .map(|tid| {
            let flags = Arc::clone(&flags);
            thread::spawn(move || {
                par_map(2, 2, move |i| {
                    flags[tid].store(true, Ordering::SeqCst);
                    let deadline = Instant::now() + Duration::from_secs(30);
                    while !flags.iter().all(|f| f.load(Ordering::SeqCst)) {
                        assert!(
                            Instant::now() < deadline,
                            "4-way epoch overlap never happened: submit serialization is back"
                        );
                        thread::sleep(Duration::from_millis(1));
                    }
                    tid * 10 + i
                })
            })
        })
        .collect();
    for (tid, h) in handles.into_iter().enumerate() {
        assert_eq!(h.join().unwrap(), vec![tid * 10, tid * 10 + 1]);
    }
    assert!(
        pool::pool_stats().peak_concurrent_epochs >= 4,
        "scheduler never held four live epochs"
    );
}

/// A panicking job aborts only its own epoch: concurrent epochs keep
/// producing exact results, and the pool serves new epochs afterwards.
#[test]
fn panicking_epoch_does_not_poison_concurrent_epochs() {
    let stop = Arc::new(AtomicBool::new(false));
    // three clean submitters hammer the pool...
    let clean: Vec<_> = (0..3)
        .map(|tid| {
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let mut epochs = 0usize;
                while !stop.load(Ordering::SeqCst) || epochs < 20 {
                    let n = 64 + 7 * tid;
                    let got = par_map(n, 4, |i| i as u64 * 3 + tid as u64);
                    let want: Vec<u64> = (0..n).map(|i| i as u64 * 3 + tid as u64).collect();
                    assert_eq!(got, want, "clean epoch corrupted by a concurrent panic");
                    epochs += 1;
                    if epochs >= 200 {
                        break;
                    }
                }
                epochs
            })
        })
        .collect();
    // ...while a fourth submitter fires panicking epochs the whole time
    for round in 0..50 {
        let poisoned = catch_unwind(AssertUnwindSafe(|| {
            par_map(32, 4, move |i| {
                if i == round % 32 {
                    panic!("boom {round}");
                }
                i
            })
        }));
        let err = poisoned.expect_err("panicking epoch must propagate to its submitter");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "pool worker panicked");
    }
    stop.store(true, Ordering::SeqCst);
    for h in clean {
        assert!(h.join().expect("clean thread poisoned") >= 20);
    }
    // the pool is still fully functional after 50 panicked epochs
    assert_eq!(par_map(100, 4, |i| i + 1), (0..100).map(|i| i + 1).collect::<Vec<_>>());
}
