//! End-to-end acceptance tests for the `search` subsystem: exactness
//! vs brute force on fixed synthetic workloads, cell savings, the
//! coordinator Search path, and the TCP protocol ops.

use std::sync::Arc;

use spdtw::classify::nn::{classify_knn, classify_knn_indexed};
use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::measures::dtw::{dtw_banded, BandedDtw};
use spdtw::measures::spdtw::SpDtw;
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::util::json::Json;

/// THE acceptance invariant: on a fixed synthetic workload the engine
/// returns bit-identical k-NN results to brute force while computing
/// strictly fewer full DP cells.
#[test]
fn search_is_exact_and_strictly_cheaper_than_brute_force() {
    let ds = synthetic::generate_scaled("CBF", 42, 30, 25).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round() as usize;
    let index = Arc::new(Index::build(&ds.train, band, 4));
    let engine = SearchEngine::new(Arc::clone(&index), Cascade::default());

    for k in [1usize, 3] {
        // per-query neighbor lists, bit for bit
        let mut total_stats = spdtw::search::PruneStats::default();
        for probe in &ds.test.series {
            let got = engine.knn(probe, k);
            let mut want: Vec<(f64, usize)> = ds
                .train
                .series
                .iter()
                .enumerate()
                .map(|(j, tr)| (dtw_banded(&probe.values, &tr.values, band).value, j))
                .collect();
            want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            want.truncate(k);
            assert_eq!(got.neighbors.len(), want.len());
            for (g, (wd, wj)) in got.neighbors.iter().zip(&want) {
                assert_eq!(g.dist.to_bits(), wd.to_bits(), "k={k}");
                assert_eq!(g.train_idx, *wj, "k={k}");
            }
            total_stats.merge(&got.stats);
        }
        assert_eq!(total_stats.queries, ds.test.len() as u64);
        // classification decisions identical to brute-force classify_knn
        let (eval, stats) = classify_knn_indexed(&index, Cascade::default(), &ds.test, k, 4);
        let brute = classify_knn(&BandedDtw(band), &ds.train, &ds.test, k, 4);
        assert_eq!(eval.error_rate, brute.error_rate, "k={k}");
        // strictly fewer full DP cells than the exhaustive scan
        assert!(
            stats.dp_cells < brute.visited_cells,
            "k={k}: {} DP cells vs brute {}",
            stats.dp_cells,
            brute.visited_cells
        );
        assert!(stats.pruned() > 0, "k={k}: cascade pruned nothing");
        assert_eq!(stats.candidates, brute.comparisons);
    }
}

#[test]
fn spdtw_search_composes_with_learned_loc_grid() {
    // The headline composition: cascade pruning over the paper's sparse
    // grid — fewer comparisons AND fewer cells per comparison.
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 24, 16).unwrap();
    let grid = learn_occupancy_grid(&ds.train, 4);
    let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
    assert!(loc.min_weight() >= 1.0 - 1e-12, "learned weights must be >= 1");
    let index = Arc::new(Index::build_spdtw(&ds.train, Arc::clone(&loc), 4));
    assert!(index.lb_valid);

    let (eval, stats) = classify_knn_indexed(&index, Cascade::default(), &ds.test, 1, 4);
    let sp = SpDtw::from_arc(Arc::clone(&loc));
    let brute = classify_knn(&sp, &ds.train, &ds.test, 1, 4);
    assert_eq!(eval.error_rate, brute.error_rate);
    assert!(stats.dp_cells < brute.visited_cells);
    assert!(stats.pruned() > 0);
}

#[test]
fn cascade_stage_ablations_stay_exact() {
    let ds = synthetic::generate_scaled("Gun-Point", 11, 20, 12).unwrap();
    let band = 5;
    let index = Arc::new(Index::build(&ds.train, band, 2));
    let brute = classify_knn(&BandedDtw(band), &ds.train, &ds.test, 1, 2);
    let variants = [
        Cascade::default(),
        Cascade { kim: false, ..Cascade::default() },
        Cascade { keogh_rev: false, ..Cascade::default() },
        Cascade { early_abandon: false, ..Cascade::default() },
        Cascade { order_by_lb: false, ..Cascade::default() },
        Cascade::none(),
    ];
    for cas in variants {
        let (eval, _) = classify_knn_indexed(&index, cas, &ds.test, 1, 2);
        assert_eq!(eval.error_rate, brute.error_rate, "{cas:?}");
    }
}

#[test]
fn znormalized_search_matches_bruteforce_on_znormalized_sets() {
    // the engine z-normalizes queries itself; brute force must see
    // pre-normalized copies of both splits to agree bit-for-bit.
    let ds = synthetic::generate_scaled("Gun-Point", 6, 18, 10).unwrap();
    let band = 7;
    let index = Arc::new(Index::build_znormalized(&ds.train, band, 2));
    let (eval, stats) = classify_knn_indexed(&index, Cascade::default(), &ds.test, 1, 2);
    let mut tr = ds.train.clone();
    let mut te = ds.test.clone();
    tr.znormalize();
    te.znormalize();
    let brute = classify_knn(&BandedDtw(band), &tr, &te, 1, 2);
    assert_eq!(eval.error_rate, brute.error_rate);
    assert!(stats.dp_cells < brute.visited_cells);
}

#[test]
fn coordinator_search_request_end_to_end() {
    let ds = synthetic::generate_scaled("CBF", 8, 16, 6).unwrap();
    let band = 6;
    let coord = Coordinator::start(CoordinatorConfig::default(), None).unwrap();
    let key = coord.register_index(Index::build(&ds.train, band, 2));

    let tickets: Vec<_> = ds
        .test
        .series
        .iter()
        .map(|probe| coord.submit_search(key, probe, 2, Cascade::default()).unwrap())
        .collect();
    for (probe, ticket) in ds.test.series.iter().zip(tickets) {
        let out = ticket.wait().unwrap();
        assert_eq!(out.neighbors.len(), 2);
        // spot-check the nearest against a direct evaluation
        let direct = dtw_banded(
            &probe.values,
            &ds.train.series[out.neighbors[0].train_idx].values,
            band,
        )
        .value;
        assert_eq!(out.neighbors[0].dist.to_bits(), direct.to_bits());
    }
    coord.wait_native_idle();
    let snap = coord.metrics();
    assert_eq!(snap.search_queries, ds.test.len() as u64);
    assert_eq!(
        snap.search_candidates,
        (ds.test.len() * ds.train.len()) as u64
    );
    assert_eq!(
        snap.lb_kim_skips
            + snap.lb_keogh_skips
            + snap.lb_rev_skips
            + snap.early_abandons
            + snap.full_dp_evals,
        snap.search_candidates
    );
    assert!(snap.search_prune_ratio() > 0.0);
    assert!(snap.report().contains("search:"));
}

#[test]
fn tcp_search_protocol_roundtrip() {
    let ds = synthetic::generate_scaled("CBF", 15, 8, 2).unwrap();
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let mut server = Server::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();

    let series_json: Vec<String> = ds
        .train
        .series
        .iter()
        .map(|s| {
            let vals: Vec<String> = s.values.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    let labels: Vec<String> = ds.train.series.iter().map(|s| s.label.to_string()).collect();
    let reg = client
        .call(
            &Json::parse(&format!(
                r#"{{"op":"register_index","band":6,"series":[{}],"labels":[{}]}}"#,
                series_json.join(","),
                labels.join(",")
            ))
            .unwrap(),
        )
        .unwrap();
    assert_eq!(reg.get("ok"), Some(&Json::Bool(true)), "{reg:?}");
    let idx = reg.req_usize("index").unwrap();

    let qvals: Vec<String> = ds.test.series[0]
        .values
        .iter()
        .map(|v| format!("{v}"))
        .collect();
    let r = client
        .call(
            &Json::parse(&format!(
                r#"{{"op":"search","index":{idx},"k":3,"x":[{}]}}"#,
                qvals.join(",")
            ))
            .unwrap(),
        )
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.req_arr("neighbors").unwrap().len(), 3);
    assert_eq!(
        r.req_f64("candidates").unwrap(),
        ds.train.len() as f64
    );
    server.stop();
}
