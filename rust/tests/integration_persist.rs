//! End-to-end acceptance tests for the persistent index store: a saved
//! index reloads to byte-identical k-NN behavior, every corruption mode
//! is rejected with a clean error (never a wrong answer), and a fresh
//! coordinator warm-starts from the store — through both the Rust API
//! and the TCP `register_index` protocol.

use std::path::PathBuf;
use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::runtime::Manifest;
use spdtw::search::{persist, Cascade, Index, SearchEngine};
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::sparse::LocMatrix;
use spdtw::util::json::Json;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spdtw_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// THE acceptance invariant: save → load → bit-identical k-NN results
/// to the freshly built index, across banded, z-normalized and SP-DTW
/// (learned-grid) index flavors.
#[test]
#[cfg_attr(miri, ignore = "file IO; the resealed matrices cover the loader under Miri")]
fn saved_index_reloads_to_byte_identical_knn() {
    let dir = temp_dir("roundtrip");
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 24, 16).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round() as usize;

    let grid = learn_occupancy_grid(&ds.train, 4);
    let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
    let variants: Vec<(&str, Index)> = vec![
        ("banded", Index::build(&ds.train, band, 4)),
        ("znorm", Index::build_znormalized(&ds.train, band, 4)),
        ("spdtw", Index::build_spdtw(&ds.train, loc, 4)),
    ];

    for (tag, built) in variants {
        let path = dir.join(format!("{tag}.spix"));
        persist::save_index(&built, &path).unwrap();
        let loaded = persist::load_index(&path).unwrap();

        // stored state is bit-exact
        assert_eq!(built.t, loaded.t, "{tag}");
        assert_eq!(built.radius, loaded.radius, "{tag}");
        assert_eq!(built.band, loaded.band, "{tag}");
        assert_eq!(built.labels, loaded.labels, "{tag}");
        assert_eq!(built.znormalized, loaded.znormalized, "{tag}");
        assert_eq!(built.lb_valid, loaded.lb_valid, "{tag}");
        for (a, b) in built.series.iter().zip(&loaded.series) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} series bytes");
            }
        }
        for ((ua, la), (ub, lb)) in built.envs.iter().zip(&loaded.envs) {
            for (x, y) in ua.iter().zip(ub).chain(la.iter().zip(lb)) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} envelope bytes");
            }
        }

        // ...and so are the search results, for every cascade config
        for cascade in [Cascade::default(), Cascade::none()] {
            let fresh = SearchEngine::new(Arc::new(built.clone()), cascade);
            let warm = SearchEngine::new(Arc::new(loaded.clone()), cascade);
            for probe in &ds.test.series {
                for k in [1usize, 3] {
                    let a = fresh.knn(probe, k);
                    let b = warm.knn(probe, k);
                    assert_eq!(a.neighbors.len(), b.neighbors.len(), "{tag}");
                    for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
                        assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{tag}");
                        assert_eq!(x.train_idx, y.train_idx, "{tag}");
                        assert_eq!(x.label, y.label, "{tag}");
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Every way a file can go bad must produce a clean `Err` — truncation
/// at any point, a flipped byte anywhere, a bumped version, foreign
/// magic — and never a partially-working index.
#[test]
#[cfg_attr(miri, ignore = "file IO; the resealed matrices cover the loader under Miri")]
fn corrupted_files_are_rejected_never_misloaded() {
    let dir = temp_dir("corrupt");
    let ds = synthetic::generate_scaled("CBF", 7, 10, 2).unwrap();
    let index = Index::build(&ds.train, 5, 2);
    let path = dir.join("cbf.spix");
    persist::save_index(&index, &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // truncation sweep (header boundary, payload, last byte)
    for frac in [0usize, 1, 7, 23, 24, 60, good.len() / 2, good.len() - 1] {
        std::fs::write(&path, &good[..frac]).unwrap();
        assert!(
            persist::load_index(&path).is_err(),
            "truncation to {frac} bytes was accepted"
        );
    }

    // bit flips across the whole file: header fields, dims, payload
    for pos in (0..good.len()).step_by((good.len() / 13).max(1)) {
        let mut bad = good.clone();
        bad[pos] ^= 0x20;
        std::fs::write(&path, &bad).unwrap();
        assert!(
            persist::load_index(&path).is_err(),
            "flipped byte at {pos} was accepted"
        );
    }

    // version bump
    let mut bumped = good.clone();
    bumped[4] = bumped[4].wrapping_add(1);
    std::fs::write(&path, &bumped).unwrap();
    let err = persist::load_index(&path).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // trailing garbage
    let mut padded = good.clone();
    padded.extend_from_slice(&[0u8; 16]);
    std::fs::write(&path, &padded).unwrap();
    assert!(persist::load_index(&path).is_err());

    // the pristine bytes still load (the sweep didn't overfit)
    std::fs::write(&path, &good).unwrap();
    assert!(persist::load_index(&path).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Warm start through the coordinator: process A persists on register,
/// process B (a fresh Coordinator) serves the same neighbors without a
/// rebuild, reporting `loaded_from_disk` over TCP.
#[test]
#[cfg_attr(miri, ignore = "file IO; the resealed matrices cover the loader under Miri")]
fn coordinator_warm_start_serves_identical_results() {
    let store = temp_dir("warm");
    let ds = synthetic::generate_scaled("Gun-Point", 13, 16, 8).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round() as usize;
    let mut cfg = CoordinatorConfig::default();
    cfg.workers = 2;
    cfg.index_store = Some(store.clone());

    // ---- "process A": build, register persistently, record answers ----
    let baseline: Vec<Vec<(u64, usize)>> = {
        let c = Coordinator::start(cfg.clone(), None).unwrap();
        let key = c
            .register_index_persistent("gun", Index::build(&ds.train, band, 2))
            .unwrap();
        assert_eq!(c.metrics().indexes_saved, 1);
        let answers = ds
            .test
            .series
            .iter()
            .map(|probe| {
                c.submit_search(key, probe, 3, Cascade::default())
                    .unwrap()
                    .wait()
                    .unwrap()
                    .neighbors
                    .iter()
                    .map(|n| (n.dist.to_bits(), n.train_idx))
                    .collect()
            })
            .collect();
        c.wait_native_idle();
        answers
    };

    // the store manifest records the index next to the artifact entries
    let manifest = Manifest::load(&store).unwrap();
    let entry = manifest.find_index("gun").expect("manifest entry missing");
    assert_eq!(entry.length, t);
    assert_eq!(entry.count, ds.train.len());
    assert!(entry.path.exists());

    // ---- "process B": warm start, same key lookup, same answers --------
    let c2 = Coordinator::start(cfg.clone(), None).unwrap();
    let snap = c2.metrics();
    assert_eq!(snap.indexes_loaded, 1);
    assert_eq!(snap.index_load_failures, 0);
    let (key, loaded) = c2.lookup_index_named("gun").expect("warm index missing");
    assert!(loaded);
    for (probe, want) in ds.test.series.iter().zip(&baseline) {
        let got = c2
            .submit_search(key, probe, 3, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        let got: Vec<(u64, usize)> = got
            .neighbors
            .iter()
            .map(|n| (n.dist.to_bits(), n.train_idx))
            .collect();
        assert_eq!(&got, want, "warm-started index diverged");
    }
    c2.wait_native_idle();
    drop(c2);

    // ---- TCP surface: named register resolves warm, search works -------
    let c3 = Arc::new(Coordinator::start(cfg, None).unwrap());
    let mut server = Server::start(Arc::clone(&c3), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let series_json: Vec<String> = ds
        .train
        .series
        .iter()
        .map(|s| {
            let vals: Vec<String> = s.values.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    let req = format!(
        r#"{{"op":"register_index","name":"gun","band":{band},"series":[{}]}}"#,
        series_json.join(",")
    );
    let reply = client.call(&Json::parse(&req).unwrap()).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    assert_eq!(
        reply.get("loaded_from_disk"),
        Some(&Json::Bool(true)),
        "warm-started name should be served from disk: {reply:?}"
    );
    // memory report must include the real footprint (labels at least)
    let mem = reply.req_f64("memory_bytes").unwrap() as usize;
    assert!(mem >= ds.train.len() * (t * 8 * 3 + 8), "memory under-reported: {mem}");
    server.stop();
    std::fs::remove_dir_all(&store).ok();
}

/// A corrupt store never reaches serving: the warm start skips the bad
/// file, counts the rejection, and a named re-register rebuilds cleanly.
#[test]
#[cfg_attr(miri, ignore = "file IO; the resealed matrices cover the loader under Miri")]
fn warm_start_skips_corrupt_store_and_rebuilds() {
    let store = temp_dir("warmbad");
    let ds = synthetic::generate_scaled("CBF", 3, 8, 4).unwrap();
    let mut cfg = CoordinatorConfig::default();
    cfg.workers = 2;
    cfg.index_store = Some(store.clone());
    {
        let c = Coordinator::start(cfg.clone(), None).unwrap();
        c.register_index_persistent("cbf", Index::build(&ds.train, 4, 2))
            .unwrap();
    }
    // corrupt the payload on disk
    let path = store.join("cbf.spix");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x55;
    std::fs::write(&path, &bytes).unwrap();

    let c2 = Coordinator::start(cfg, None).unwrap();
    assert_eq!(c2.lookup_index_named("cbf"), None);
    assert_eq!(c2.metrics().index_load_failures, 1);

    // re-registering the name rebuilds and re-persists a good file
    let key = c2
        .register_index_persistent("cbf", Index::build(&ds.train, 4, 2))
        .unwrap();
    assert_eq!(c2.lookup_index_named("cbf"), Some((key, false)));
    assert!(persist::load_index(&path).is_ok());
    std::fs::remove_dir_all(&store).ok();
}

/// Rebuild a valid header (magic, version, length, checksum) around a
/// doctored payload, so the corruption reaches the semantic validators
/// in `from_bytes` instead of dying at the checksum gate.  This is the
/// deterministic promotion of the `fuzz_spix` corpus shapes: the fuzzer
/// explores this space randomly in CI, these cases pin the invariants
/// forever.
fn reseal(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + payload.len());
    out.extend_from_slice(b"SPIX");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&persist::fnv1a64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn put_u64(payload: &mut [u8], off: usize, v: u64) {
    payload[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

/// Payload field offsets (see the format doc in `search::persist`):
/// flags u32 @0, then u64 dims t@4, radius@12, band@20, n@28, nnz@36,
/// labels from 44, series rows after the labels.
const OFF_T: usize = 4;
const OFF_RADIUS: usize = 12;
const OFF_BAND: usize = 20;
const OFF_N: usize = 28;
const OFF_NNZ: usize = 36;

/// Every semantic invariant the loader enforces *behind* the checksum:
/// a well-sealed file with inconsistent contents must still be a clean
/// `Err`, never a mis-built index.  Pure in-memory (`to_bytes` /
/// `from_bytes`), so it also runs under Miri.
#[test]
fn resealed_semantic_corruption_is_rejected() {
    let ds = synthetic::generate_scaled("CBF", 11, 10, 2).unwrap();
    let t = ds.series_len();
    let n = ds.train.len();
    let band = 3usize;
    assert!(band + 1 < t, "base index needs band headroom");
    let payload = persist::to_bytes(&Index::build(&ds.train, band, 2))[24..].to_vec();

    // Control first: resealing the untouched payload must load, or the
    // matrix below would pass vacuously.
    persist::from_bytes(&reseal(&payload)).expect("reseal control failed");

    type Mutation = Box<dyn Fn(&mut Vec<u8>)>;
    let series_start = 44 + n * 8;
    let cases: Vec<(&str, Mutation, &str)> = vec![
        (
            "unknown flag bit",
            Box::new(|p: &mut Vec<u8>| p[0] |= 1 << 3),
            "unknown flag bits",
        ),
        (
            "zero series length",
            Box::new(|p: &mut Vec<u8>| put_u64(p, OFF_T, 0)),
            "empty index",
        ),
        (
            "zero series count",
            Box::new(|p: &mut Vec<u8>| put_u64(p, OFF_N, 0)),
            "empty index",
        ),
        (
            "radius >= t",
            Box::new(move |p: &mut Vec<u8>| put_u64(p, OFF_RADIUS, t as u64)),
            "out of range",
        ),
        (
            "grid entries without grid flag",
            Box::new(|p: &mut Vec<u8>| put_u64(p, OFF_NNZ, 1)),
            "disagrees with entry count",
        ),
        (
            "dims disagree with payload size",
            Box::new(move |p: &mut Vec<u8>| put_u64(p, OFF_N, (n + 1) as u64)),
            "dims require",
        ),
        (
            "radius inconsistent with band",
            Box::new(move |p: &mut Vec<u8>| put_u64(p, OFF_RADIUS, (band - 1) as u64)),
            "inconsistent with band",
        ),
        (
            "envelope no longer bounds its series",
            Box::new(move |p: &mut Vec<u8>| {
                p[series_start..series_start + 8].copy_from_slice(&1e300f64.to_le_bytes());
            }),
            "does not bound",
        ),
        (
            "payload truncated behind a fixed-up header",
            Box::new(|p: &mut Vec<u8>| {
                let cut = p.len() - 8;
                p.truncate(cut);
            }),
            "dims require",
        ),
    ];
    for (what, mutate, want) in cases {
        let mut bad = payload.clone();
        mutate(&mut bad);
        let err = persist::from_bytes(&reseal(&bad))
            .map(|_| ())
            .expect_err(&format!("{what}: loader accepted the file"));
        let msg = err.to_string();
        assert!(msg.contains(want), "{what}: got {msg:?}, wanted {want:?}");
    }
}

/// Same matrix for the grid-index flavor: the band sentinel, the
/// radius/grid-reach admissibility link, and the grid triples
/// themselves (out-of-range coordinates, non-finite weights).
#[test]
fn resealed_grid_corruption_is_rejected() {
    let ds = synthetic::generate_scaled("CBF", 5, 8, 2).unwrap();
    let t = ds.series_len();
    let n = ds.train.len();
    // Diagonal plus one off-diagonal cell: max band offset is exactly 1,
    // so shrinking the stored radius to 0 must trip the reach check.
    let mut triples: Vec<(usize, usize, f64)> = (0..t).map(|i| (i, i, 1.0)).collect();
    triples.push((0, 1, 1.0));
    let loc = Arc::new(LocMatrix::from_triples(t, triples));
    let payload = persist::to_bytes(&Index::build_spdtw(&ds.train, loc, 2))[24..].to_vec();
    persist::from_bytes(&reseal(&payload)).expect("grid reseal control failed");

    let grid_start = 44 + n * 8 + n * t * 24;

    let mut banded = payload.clone();
    put_u64(&mut banded, OFF_BAND, (t - 1) as u64);
    let msg = persist::from_bytes(&reseal(&banded))
        .map(|_| ())
        .expect_err("bounded band accepted on grid index")
        .to_string();
    assert!(msg.contains("unbounded band"), "{msg}");

    let mut narrow = payload.clone();
    put_u64(&mut narrow, OFF_RADIUS, 0);
    let msg = persist::from_bytes(&reseal(&narrow))
        .map(|_| ())
        .expect_err("radius below grid reach accepted")
        .to_string();
    assert!(msg.contains("narrower than grid reach"), "{msg}");

    // Grid triples: row index pushed out of [0, t), then a NaN weight.
    let mut out_of_range = payload.clone();
    out_of_range[grid_start..grid_start + 4].copy_from_slice(&(t as u32).to_le_bytes());
    assert!(persist::from_bytes(&reseal(&out_of_range)).is_err());

    let mut nan_weight = payload.clone();
    nan_weight[grid_start + 8..grid_start + 16].copy_from_slice(&f64::NAN.to_le_bytes());
    assert!(persist::from_bytes(&reseal(&nan_weight)).is_err());
}

/// `inspect` reads dimensions without a full load and flags bad
/// checksums instead of erroring.
#[test]
#[cfg_attr(miri, ignore = "file IO; the resealed matrices cover the loader under Miri")]
fn inspect_summarizes_and_flags_corruption() {
    let dir = temp_dir("inspect");
    let ds = synthetic::generate_scaled("CBF", 9, 6, 2).unwrap();
    let grid = learn_occupancy_grid(&ds.train, 2);
    let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
    let nnz = loc.nnz();
    let index = Index::build_spdtw(&ds.train, loc, 2);
    let path = dir.join("sp.spix");
    persist::save_index(&index, &path).unwrap();

    let info = persist::inspect(&path).unwrap();
    assert!(info.checksum_ok);
    assert_eq!(info.t, index.t);
    assert_eq!(info.n, index.len());
    assert_eq!(info.radius, index.radius);
    assert_eq!(info.grid_nnz, Some(nnz));
    assert_eq!(info.znormalized, false);

    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 1;
    std::fs::write(&path, &bytes).unwrap();
    assert!(!persist::inspect(&path).unwrap().checksum_ok);
    std::fs::remove_dir_all(&dir).ok();
}
