//! End-to-end acceptance tests for the persistent index store: a saved
//! index reloads to byte-identical k-NN behavior, every corruption mode
//! is rejected with a clean error (never a wrong answer), and a fresh
//! coordinator warm-starts from the store — through both the Rust API
//! and the TCP `register_index` protocol.

use std::path::PathBuf;
use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::runtime::Manifest;
use spdtw::search::{persist, Cascade, Index, SearchEngine};
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::util::json::Json;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spdtw_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// THE acceptance invariant: save → load → bit-identical k-NN results
/// to the freshly built index, across banded, z-normalized and SP-DTW
/// (learned-grid) index flavors.
#[test]
fn saved_index_reloads_to_byte_identical_knn() {
    let dir = temp_dir("roundtrip");
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 24, 16).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round() as usize;

    let grid = learn_occupancy_grid(&ds.train, 4);
    let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
    let variants: Vec<(&str, Index)> = vec![
        ("banded", Index::build(&ds.train, band, 4)),
        ("znorm", Index::build_znormalized(&ds.train, band, 4)),
        ("spdtw", Index::build_spdtw(&ds.train, loc, 4)),
    ];

    for (tag, built) in variants {
        let path = dir.join(format!("{tag}.spix"));
        persist::save_index(&built, &path).unwrap();
        let loaded = persist::load_index(&path).unwrap();

        // stored state is bit-exact
        assert_eq!(built.t, loaded.t, "{tag}");
        assert_eq!(built.radius, loaded.radius, "{tag}");
        assert_eq!(built.band, loaded.band, "{tag}");
        assert_eq!(built.labels, loaded.labels, "{tag}");
        assert_eq!(built.znormalized, loaded.znormalized, "{tag}");
        assert_eq!(built.lb_valid, loaded.lb_valid, "{tag}");
        for (a, b) in built.series.iter().zip(&loaded.series) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} series bytes");
            }
        }
        for ((ua, la), (ub, lb)) in built.envs.iter().zip(&loaded.envs) {
            for (x, y) in ua.iter().zip(ub).chain(la.iter().zip(lb)) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} envelope bytes");
            }
        }

        // ...and so are the search results, for every cascade config
        for cascade in [Cascade::default(), Cascade::none()] {
            let fresh = SearchEngine::new(Arc::new(built.clone()), cascade);
            let warm = SearchEngine::new(Arc::new(loaded.clone()), cascade);
            for probe in &ds.test.series {
                for k in [1usize, 3] {
                    let a = fresh.knn(probe, k);
                    let b = warm.knn(probe, k);
                    assert_eq!(a.neighbors.len(), b.neighbors.len(), "{tag}");
                    for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
                        assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{tag}");
                        assert_eq!(x.train_idx, y.train_idx, "{tag}");
                        assert_eq!(x.label, y.label, "{tag}");
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Every way a file can go bad must produce a clean `Err` — truncation
/// at any point, a flipped byte anywhere, a bumped version, foreign
/// magic — and never a partially-working index.
#[test]
fn corrupted_files_are_rejected_never_misloaded() {
    let dir = temp_dir("corrupt");
    let ds = synthetic::generate_scaled("CBF", 7, 10, 2).unwrap();
    let index = Index::build(&ds.train, 5, 2);
    let path = dir.join("cbf.spix");
    persist::save_index(&index, &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // truncation sweep (header boundary, payload, last byte)
    for frac in [0usize, 1, 7, 23, 24, 60, good.len() / 2, good.len() - 1] {
        std::fs::write(&path, &good[..frac]).unwrap();
        assert!(
            persist::load_index(&path).is_err(),
            "truncation to {frac} bytes was accepted"
        );
    }

    // bit flips across the whole file: header fields, dims, payload
    for pos in (0..good.len()).step_by((good.len() / 13).max(1)) {
        let mut bad = good.clone();
        bad[pos] ^= 0x20;
        std::fs::write(&path, &bad).unwrap();
        assert!(
            persist::load_index(&path).is_err(),
            "flipped byte at {pos} was accepted"
        );
    }

    // version bump
    let mut bumped = good.clone();
    bumped[4] = bumped[4].wrapping_add(1);
    std::fs::write(&path, &bumped).unwrap();
    let err = persist::load_index(&path).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // trailing garbage
    let mut padded = good.clone();
    padded.extend_from_slice(&[0u8; 16]);
    std::fs::write(&path, &padded).unwrap();
    assert!(persist::load_index(&path).is_err());

    // the pristine bytes still load (the sweep didn't overfit)
    std::fs::write(&path, &good).unwrap();
    assert!(persist::load_index(&path).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Warm start through the coordinator: process A persists on register,
/// process B (a fresh Coordinator) serves the same neighbors without a
/// rebuild, reporting `loaded_from_disk` over TCP.
#[test]
fn coordinator_warm_start_serves_identical_results() {
    let store = temp_dir("warm");
    let ds = synthetic::generate_scaled("Gun-Point", 13, 16, 8).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round() as usize;
    let mut cfg = CoordinatorConfig::default();
    cfg.workers = 2;
    cfg.index_store = Some(store.clone());

    // ---- "process A": build, register persistently, record answers ----
    let baseline: Vec<Vec<(u64, usize)>> = {
        let c = Coordinator::start(cfg.clone(), None).unwrap();
        let key = c
            .register_index_persistent("gun", Index::build(&ds.train, band, 2))
            .unwrap();
        assert_eq!(c.metrics().indexes_saved, 1);
        let answers = ds
            .test
            .series
            .iter()
            .map(|probe| {
                c.submit_search(key, probe, 3, Cascade::default())
                    .unwrap()
                    .wait()
                    .unwrap()
                    .neighbors
                    .iter()
                    .map(|n| (n.dist.to_bits(), n.train_idx))
                    .collect()
            })
            .collect();
        c.wait_native_idle();
        answers
    };

    // the store manifest records the index next to the artifact entries
    let manifest = Manifest::load(&store).unwrap();
    let entry = manifest.find_index("gun").expect("manifest entry missing");
    assert_eq!(entry.length, t);
    assert_eq!(entry.count, ds.train.len());
    assert!(entry.path.exists());

    // ---- "process B": warm start, same key lookup, same answers --------
    let c2 = Coordinator::start(cfg.clone(), None).unwrap();
    let snap = c2.metrics();
    assert_eq!(snap.indexes_loaded, 1);
    assert_eq!(snap.index_load_failures, 0);
    let (key, loaded) = c2.lookup_index_named("gun").expect("warm index missing");
    assert!(loaded);
    for (probe, want) in ds.test.series.iter().zip(&baseline) {
        let got = c2
            .submit_search(key, probe, 3, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        let got: Vec<(u64, usize)> = got
            .neighbors
            .iter()
            .map(|n| (n.dist.to_bits(), n.train_idx))
            .collect();
        assert_eq!(&got, want, "warm-started index diverged");
    }
    c2.wait_native_idle();
    drop(c2);

    // ---- TCP surface: named register resolves warm, search works -------
    let c3 = Arc::new(Coordinator::start(cfg, None).unwrap());
    let mut server = Server::start(Arc::clone(&c3), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let series_json: Vec<String> = ds
        .train
        .series
        .iter()
        .map(|s| {
            let vals: Vec<String> = s.values.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    let req = format!(
        r#"{{"op":"register_index","name":"gun","band":{band},"series":[{}]}}"#,
        series_json.join(",")
    );
    let reply = client.call(&Json::parse(&req).unwrap()).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    assert_eq!(
        reply.get("loaded_from_disk"),
        Some(&Json::Bool(true)),
        "warm-started name should be served from disk: {reply:?}"
    );
    // memory report must include the real footprint (labels at least)
    let mem = reply.req_f64("memory_bytes").unwrap() as usize;
    assert!(mem >= ds.train.len() * (t * 8 * 3 + 8), "memory under-reported: {mem}");
    server.stop();
    std::fs::remove_dir_all(&store).ok();
}

/// A corrupt store never reaches serving: the warm start skips the bad
/// file, counts the rejection, and a named re-register rebuilds cleanly.
#[test]
fn warm_start_skips_corrupt_store_and_rebuilds() {
    let store = temp_dir("warmbad");
    let ds = synthetic::generate_scaled("CBF", 3, 8, 4).unwrap();
    let mut cfg = CoordinatorConfig::default();
    cfg.workers = 2;
    cfg.index_store = Some(store.clone());
    {
        let c = Coordinator::start(cfg.clone(), None).unwrap();
        c.register_index_persistent("cbf", Index::build(&ds.train, 4, 2))
            .unwrap();
    }
    // corrupt the payload on disk
    let path = store.join("cbf.spix");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x55;
    std::fs::write(&path, &bytes).unwrap();

    let c2 = Coordinator::start(cfg, None).unwrap();
    assert_eq!(c2.lookup_index_named("cbf"), None);
    assert_eq!(c2.metrics().index_load_failures, 1);

    // re-registering the name rebuilds and re-persists a good file
    let key = c2
        .register_index_persistent("cbf", Index::build(&ds.train, 4, 2))
        .unwrap();
    assert_eq!(c2.lookup_index_named("cbf"), Some((key, false)));
    assert!(persist::load_index(&path).is_ok());
    std::fs::remove_dir_all(&store).ok();
}

/// `inspect` reads dimensions without a full load and flags bad
/// checksums instead of erroring.
#[test]
fn inspect_summarizes_and_flags_corruption() {
    let dir = temp_dir("inspect");
    let ds = synthetic::generate_scaled("CBF", 9, 6, 2).unwrap();
    let grid = learn_occupancy_grid(&ds.train, 2);
    let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
    let nnz = loc.nnz();
    let index = Index::build_spdtw(&ds.train, loc, 2);
    let path = dir.join("sp.spix");
    persist::save_index(&index, &path).unwrap();

    let info = persist::inspect(&path).unwrap();
    assert!(info.checksum_ok);
    assert_eq!(info.t, index.t);
    assert_eq!(info.n, index.len());
    assert_eq!(info.radius, index.radius);
    assert_eq!(info.grid_nnz, Some(nnz));
    assert_eq!(info.znormalized, false);

    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 1;
    std::fs::write(&path, &bytes).unwrap();
    assert!(!persist::inspect(&path).unwrap().checksum_ok);
    std::fs::remove_dir_all(&dir).ok();
}
