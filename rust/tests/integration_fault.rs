//! Deterministic chaos suite for the fault-tolerant sharded serving
//! path, driven by seeded [`FaultPlan`]s instead of wall-clock luck:
//!
//! 1. degraded answers are *typed and opt-in* — a dead shard yields the
//!    `unavailable` error by default and an exact, explicitly-flagged
//!    `partial` merge only under `allow_partial: true`, never an
//!    unflagged subset;
//! 2. a link that fails `breaker_threshold` consecutive times opens its
//!    circuit breaker and fails fast (provably without dialing), and a
//!    health probe closes it again once the shard recovers;
//! 3. a client `deadline_ms` budget beats a slow shard leg with the
//!    typed `deadline_exceeded` code, end to end over the wire;
//! 4. the same plan + seed against the same request script reproduces
//!    the same reply sequence;
//! 5. single transient faults (garbled line, torn reply) self-heal
//!    through the inline reconnect-retry with no degradation at all.

use std::sync::Arc;
use std::time::Duration;

use spdtw::config::{CoordinatorConfig, ShardRole};
use spdtw::coordinator::request::Deadline;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::{LabeledSet, TimeSeries};
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::shard::{
    ActiveFaults, FaultPlan, FrontServer, QueryOpts, ShardClientConfig, ShardCoordinator,
    ShardNeighbor, ShardRegistration,
};
use spdtw::util::json::Json;
use spdtw::util::rng::Pcg64;

fn shard_cfg(shard_id: usize, shards_total: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        shard: Some(ShardRole {
            shard_id,
            shards_total,
        }),
        workers: 2,
        ..Default::default()
    }
}

fn start_plain_shard(shard_id: usize, shards_total: usize) -> Server {
    let coord = Arc::new(Coordinator::start(shard_cfg(shard_id, shards_total), None).unwrap());
    Server::start(coord, "127.0.0.1:0").unwrap()
}

/// A shard server acting out a fault plan — the same wiring as
/// `spdtw shard-serve --fault-plan FILE`.
fn start_faulted_shard(shard_id: usize, shards_total: usize, plan_json: &str) -> Server {
    let plan = FaultPlan::from_json(&Json::parse(plan_json).unwrap()).unwrap();
    let coord = Arc::new(Coordinator::start(shard_cfg(shard_id, shards_total), None).unwrap());
    Server::start_with_faults(coord, "127.0.0.1:0", Arc::new(ActiveFaults::new(plan))).unwrap()
}

/// `connect_attempts: 1` keeps the per-shard connect-event accounting
/// exact (one dial per reconnect), which is what lets these tests prove
/// breaker/probe behavior from fault-window arithmetic alone.
fn fleet_cfg(servers: &[Server], breaker_threshold: u32) -> ShardClientConfig {
    ShardClientConfig {
        addrs: servers.iter().map(|s| s.addr.to_string()).collect(),
        connect_attempts: 1,
        backoff_base_ms: 5,
        backoff_cap_ms: 20,
        call_timeout_ms: 2_000,
        breaker_threshold,
        probe_interval_ms: 0, // probes driven manually via probe_once()
        store: None,
    }
}

fn random_series(rng: &mut Pcg64, n: usize, t: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..t).map(|_| rng.range(-2.0, 2.0)).collect())
        .collect()
}

fn labeled(series: &[Vec<f64>], labels: &[usize]) -> LabeledSet {
    LabeledSet::new(
        series
            .iter()
            .zip(labels)
            .map(|(v, &l)| TimeSeries::new(l, v.clone()))
            .collect(),
    )
}

/// Reference engine over one shard's slice of the corpus (round-robin:
/// global id `g` lives on shard `g % shards_total`).
fn sub_engine(series: &[Vec<f64>], labels: &[usize], part: &[usize], band: usize) -> SearchEngine {
    let s: Vec<Vec<f64>> = part.iter().map(|&g| series[g].clone()).collect();
    let l: Vec<usize> = part.iter().map(|&g| labels[g]).collect();
    SearchEngine::new(
        Arc::new(Index::build(&labeled(&s, &l), band, 1)),
        Cascade::default(),
    )
}

/// The engine's exact top-k remapped to global index space — what a
/// partial merge over exactly this shard must return, bit for bit.
fn expect_list(engine: &SearchEngine, part: &[usize], query: &[f64], k: usize) -> Vec<ShardNeighbor> {
    engine
        .knn_values(query, k)
        .neighbors
        .iter()
        .map(|nb| ShardNeighbor {
            dist: nb.dist,
            label: nb.label,
            global_idx: part[nb.train_idx],
        })
        .collect()
}

fn assert_neighbors_eq(got: &[ShardNeighbor], want: &[ShardNeighbor], ctx: &dyn std::fmt::Display) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "{ctx}");
        assert_eq!(g.global_idx, w.global_idx, "{ctx}");
        assert_eq!(g.label, w.label, "{ctx}");
    }
}

fn register_corpus(
    sc: &ShardCoordinator,
    series: &[Vec<f64>],
    labels: &[usize],
    band: usize,
) -> u64 {
    sc.register(&ShardRegistration {
        name: None,
        series: series.to_vec(),
        labels: labels.to_vec(),
        band: Some(band),
        measure: None,
    })
    .unwrap()
    .key
}

fn partial_opts() -> QueryOpts {
    QueryOpts {
        allow_partial: true,
        deadline: None,
    }
}

// ---------------------------------------------------------------------------
// 1. opt-in partial results: exact over survivors, always flagged
// ---------------------------------------------------------------------------

/// With one shard dead, the default contract stays the typed
/// `unavailable` error; `allow_partial: true` instead returns the exact
/// merge over the surviving shard — bit-identical to an engine built on
/// that shard's slice alone — flagged with `missing`/`shards_ok` on the
/// library API and a `partial` block on the wire.  Hammering the front
/// never produces an unflagged subset.
#[test]
fn partial_results_are_exact_flagged_and_opt_in() {
    let mut servers: Vec<Server> = (0..2).map(|i| start_plain_shard(i, 2)).collect();
    let sc = ShardCoordinator::connect(fleet_cfg(&servers, 100)).unwrap();

    let mut rng = Pcg64::new(0xfa17_0001);
    let (n, t, band, k) = (10usize, 6usize, 1usize, 3usize);
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
    let key = register_corpus(&sc, &series, &labels, band);

    // round-robin layout: shard 0 survives with global ids 0, 2, 4, …
    let part0: Vec<usize> = (0..n).filter(|g| g % 2 == 0).collect();
    let survivor = sub_engine(&series, &labels, &part0, band);

    // kill shard 1: wire shutdown, then the server (and its port) go away
    let s1 = servers.pop().unwrap();
    let mut killer = Client::connect(&s1.addr).unwrap();
    let r = killer.call(&Json::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    drop(s1);

    let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();

    // default: typed unavailable, no neighbor list at all
    let err = sc.search(key, &query, k, None).unwrap_err();
    assert_eq!(err.code(), "unavailable");

    // opt-in: exact over the survivor, flagged with the missing shard
    let out = sc.search_opts(key, &query, k, None, partial_opts()).unwrap();
    assert_eq!(out.missing, vec![1]);
    assert_eq!(out.shards_ok, 1);
    assert_eq!(out.shards_total, 2);
    let want = expect_list(&survivor, &part0, &query, k);
    assert_neighbors_eq(&out.neighbors, &want, &"library partial search");

    // batch: one dead leg is missing from every query, each still exact
    let queries: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..t).map(|_| rng.range(-2.0, 2.0)).collect())
        .collect();
    let outs = sc
        .batch_search_opts(key, &queries, k, None, partial_opts())
        .unwrap();
    assert_eq!(outs.len(), queries.len());
    for (q, out) in queries.iter().zip(&outs) {
        assert_eq!(out.missing, vec![1]);
        assert_eq!(out.shards_ok, 1);
        let want = expect_list(&survivor, &part0, q, k);
        assert_neighbors_eq(&out.neighbors, &want, &"library partial batch");
    }

    // the same contract over the wire through the front
    let front = FrontServer::start(Arc::clone(&sc), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&front.addr).unwrap();
    let search_req = |allow: Option<Json>| {
        let mut fields = vec![
            ("op", Json::str("search")),
            ("index", Json::num(key as f64)),
            ("k", Json::num(k as f64)),
            ("x", Json::arr(query.iter().copied().map(Json::num))),
        ];
        if let Some(a) = allow {
            fields.push(("allow_partial", a));
        }
        Json::obj(fields)
    };

    // allow_partial is strictly boolean: anything else is bad_request
    let reply = client.call(&search_req(Some(Json::str("yes")))).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply:?}");
    assert_eq!(reply.req_str("code").unwrap(), "bad_request");

    // hammer the front: every reply is either a typed error or an exact,
    // explicitly-flagged partial — never an unflagged subset
    let want = expect_list(&survivor, &part0, &query, k);
    for round in 0..6 {
        let allow = round % 2 == 0;
        let reply = client
            .call(&search_req(allow.then_some(Json::Bool(true))))
            .unwrap();
        if allow {
            assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
            let partial = reply.get("partial").expect("partial block must be present");
            assert_eq!(partial.req_usize("shards_ok").unwrap(), 1);
            assert_eq!(partial.req_usize("shards_total").unwrap(), 2);
            let missing: Vec<usize> = partial
                .req_arr("missing")
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(missing, vec![1]);
            let ns = reply.req_arr("neighbors").unwrap();
            assert_eq!(ns.len(), want.len(), "round {round}");
            for (j, w) in ns.iter().zip(&want) {
                assert_eq!(j.req_f64("dist").unwrap().to_bits(), w.dist.to_bits());
                assert_eq!(j.req_usize("idx").unwrap(), w.global_idx);
            }
        } else {
            assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply:?}");
            assert_eq!(reply.req_str("code").unwrap(), "unavailable");
            assert!(reply.get("neighbors").is_none(), "{reply:?}");
        }
    }

    // wire batch: per-query results plus one top-level partial block
    let breq = Json::obj(vec![
        ("op", Json::str("batch_search")),
        ("index", Json::num(key as f64)),
        ("k", Json::num(k as f64)),
        (
            "xs",
            Json::arr(
                queries
                    .iter()
                    .map(|q| Json::arr(q.iter().copied().map(Json::num))),
            ),
        ),
        ("allow_partial", Json::Bool(true)),
    ]);
    let reply = client.call(&breq).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    assert_eq!(reply.req_arr("results").unwrap().len(), queries.len());
    assert_eq!(reply.req_usize("shards_ok").unwrap(), 1);
    let partial = reply.get("partial").expect("batch partial block");
    assert_eq!(partial.req_usize("shards_ok").unwrap(), 1);

    let snap = sc.metrics();
    assert!(snap.partial_replies >= 2, "{}", snap.report());
    assert!(snap.partial_failures >= 1, "{}", snap.report());
}

// ---------------------------------------------------------------------------
// 2. circuit breaker: open after K failures, fail fast, probe recovery
// ---------------------------------------------------------------------------

/// Shard 1 acts a plan that (a) closes the initial connection after the
/// two setup replies, then (b) refuses exactly the next two dials.  With
/// `breaker_threshold: 2` the first search burns both failures and opens
/// the breaker.  The refuse window is sized so that the post-open
/// searches *provably* never dial: if they did, they would consume the
/// window and the FIRST probe would already recover the link — instead
/// probe #1 must fail (refused) and probe #2 must succeed, which the
/// test asserts.  After recovery the merge is full and exact again.
#[test]
fn breaker_opens_fails_fast_and_probe_recovers() {
    let plan = r#"{"seed": 11, "rules": [
        {"shard": 1, "kind": "close_after", "replies": 2, "from": 0, "count": 1},
        {"shard": 1, "kind": "refuse_connect", "from": 1, "count": 2}
    ]}"#;
    let servers = vec![start_plain_shard(0, 2), start_faulted_shard(1, 2, plan)];
    let sc = ShardCoordinator::connect(fleet_cfg(&servers, 2)).unwrap();

    let mut rng = Pcg64::new(0xfa17_0002);
    let (n, t, band, k) = (8usize, 6usize, 1usize, 2usize);
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
    // connect event 0 (close_after 2): verify = reply 1, register = reply
    // 2, then the server tears the connection down
    let key = register_corpus(&sc, &series, &labels, band);
    // let the link's reader thread observe the close before searching
    std::thread::sleep(Duration::from_millis(50));

    let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();

    // search 1: dead link (failure 1), retry dial hits the refuse window
    // (connect event 1, failure 2) -> breaker opens
    let err = sc.search(key, &query, k, None).unwrap_err();
    assert_eq!(err.code(), "unavailable");
    assert_eq!(sc.breaker_states(), vec!["closed", "open"]);
    let snap = sc.metrics();
    assert_eq!(snap.shards[1].breaker, "open", "{}", snap.report());
    assert_eq!(snap.shards[1].breaker_opens, 1);

    // search 2: fails fast through the open breaker (no dial — proven
    // below by the probe sequence), still the typed error
    let err = sc.search(key, &query, k, None).unwrap_err();
    assert_eq!(err.code(), "unavailable");
    assert!(err.to_string().contains("failing fast"), "{err}");

    // partial results compose with the open breaker: exact over shard 0
    let part0: Vec<usize> = (0..n).filter(|g| g % 2 == 0).collect();
    let survivor = sub_engine(&series, &labels, &part0, band);
    let out = sc.search_opts(key, &query, k, None, partial_opts()).unwrap();
    assert_eq!(out.missing, vec![1]);
    let want = expect_list(&survivor, &part0, &query, k);
    assert_neighbors_eq(&out.neighbors, &want, &"partial through open breaker");

    // probe #1 consumes the last refused dial (connect event 2): the
    // breaker must stay open.  Had any fast-failed search dialed, the
    // window would already be spent and this probe would close it.
    sc.probe_once();
    assert_eq!(sc.breaker_states(), vec!["closed", "open"]);
    let snap = sc.metrics();
    assert_eq!(snap.shards[1].probes, 1);
    assert_eq!(snap.shards[1].breaker_opens, 1); // reopen is not a new open

    // probe #2 (connect event 3, outside every window) verifies the
    // shard and closes the breaker
    sc.probe_once();
    assert_eq!(sc.breaker_states(), vec!["closed", "closed"]);
    let snap = sc.metrics();
    assert_eq!(snap.shards[1].probes, 2);
    assert!(snap.shards[1].reconnects >= 1);

    // recovered: full fan-out, exact against the union corpus
    let single = SearchEngine::new(
        Arc::new(Index::build(&labeled(&series, &labels), band, 2)),
        Cascade::default(),
    );
    let out = sc.search(key, &query, k, None).unwrap();
    assert_eq!(out.shards_ok, 2);
    assert!(out.missing.is_empty());
    let want = single.knn_values(&query, k).neighbors;
    assert_eq!(out.neighbors.len(), want.len());
    for (g, w) in out.neighbors.iter().zip(&want) {
        assert_eq!(g.dist.to_bits(), w.dist.to_bits());
        assert_eq!(g.global_idx, w.train_idx);
    }
}

// ---------------------------------------------------------------------------
// 3. deadline propagation: slow shard vs client budget
// ---------------------------------------------------------------------------

/// Shard 1 delays every post-setup reply by 400 ms.  A 100 ms client
/// budget must surface as the typed `deadline_exceeded` code — on the
/// library API, under `allow_partial` (the deadline dominates), for an
/// already-expired budget, and over the wire with the budget echoed in
/// `budget_ms`.  Deadline misses say nothing about shard health, so the
/// breaker stays closed throughout.
#[test]
fn deadline_beats_slow_shard_with_typed_code() {
    let plan = r#"{"seed": 13, "rules": [
        {"shard": 1, "kind": "delay_reply", "ms": 400, "from": 2}
    ]}"#;
    let servers = vec![start_plain_shard(0, 2), start_faulted_shard(1, 2, plan)];
    let sc = ShardCoordinator::connect(fleet_cfg(&servers, 2)).unwrap();

    let mut rng = Pcg64::new(0xfa17_0003);
    let (n, t, band, k) = (8usize, 5usize, 1usize, 2usize);
    let series = random_series(&mut rng, n, t);
    let labels = vec![0usize; n];
    // replies 0 (verify) and 1 (register) are before the delay window
    let key = register_corpus(&sc, &series, &labels, band);
    let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();

    // the slow leg exhausts the budget mid-wait
    let opts = QueryOpts::with_deadline(Some(Deadline::in_ms(100)));
    let err = sc.search_opts(key, &query, k, None, opts).unwrap_err();
    assert_eq!(err.code(), "deadline_exceeded");

    // allow_partial does not soften a deadline miss: the budget is the
    // client's contract, not a shard-health statement
    let opts = QueryOpts {
        allow_partial: true,
        deadline: Some(Deadline::in_ms(100)),
    };
    let err = sc.search_opts(key, &query, k, None, opts).unwrap_err();
    assert_eq!(err.code(), "deadline_exceeded");

    // an already-expired budget fails pre-dispatch (no leg is sent)
    let d = Deadline::in_ms(1);
    std::thread::sleep(Duration::from_millis(5));
    let opts = QueryOpts::with_deadline(Some(d));
    let err = sc.search_opts(key, &query, k, None, opts).unwrap_err();
    assert_eq!(err.code(), "deadline_exceeded");

    let snap = sc.metrics();
    assert!(snap.deadlines_exceeded >= 3, "{}", snap.report());
    // deadline misses never feed the breaker
    assert_eq!(sc.breaker_states(), vec!["closed", "closed"]);

    // end to end over the wire: typed code + the budget echoed back
    let front = FrontServer::start(Arc::clone(&sc), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&front.addr).unwrap();
    let req = Json::obj(vec![
        ("proto", Json::num(2.0)),
        ("id", Json::num(3.0)),
        ("op", Json::str("search")),
        ("index", Json::num(key as f64)),
        ("k", Json::num(k as f64)),
        ("x", Json::arr(query.iter().copied().map(Json::num))),
        ("deadline_ms", Json::num(100.0)),
    ]);
    let reply = client.call(&req).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply:?}");
    assert_eq!(reply.req_usize("id").unwrap(), 3);
    assert_eq!(reply.req_str("code").unwrap(), "deadline_exceeded");
    assert_eq!(reply.req_usize("budget_ms").unwrap(), 100);

    // deadline_ms is validated, not clamped
    let bad = Json::obj(vec![
        ("op", Json::str("search")),
        ("index", Json::num(key as f64)),
        ("k", Json::num(k as f64)),
        ("x", Json::arr(query.iter().copied().map(Json::num))),
        ("deadline_ms", Json::num(0.0)),
    ]);
    let reply = client.call(&bad).unwrap();
    assert_eq!(reply.req_str("code").unwrap(), "bad_request");
}

// ---------------------------------------------------------------------------
// 4. reproducibility: same plan + seed -> same reply sequence
// ---------------------------------------------------------------------------

/// Stable projection of a wire reply: everything except the
/// free-text `error` message, which embeds the shard's ephemeral port.
fn project(reply: &Json) -> String {
    match reply {
        Json::Obj(map) => {
            let mut m = map.clone();
            m.remove("error");
            Json::Obj(m).to_string()
        }
        other => other.to_string(),
    }
}

/// One fleet + front acting out the fixed plan, one scripted request
/// sequence, projected replies out.
fn chaos_script_run() -> Vec<String> {
    // after the two setup replies the initial connection is capped and
    // every later dial is refused: shard 1 is deterministically gone
    let plan = r#"{"seed": 7, "rules": [
        {"shard": 1, "kind": "close_after", "replies": 2, "from": 0, "count": 1},
        {"shard": 1, "kind": "refuse_connect", "from": 1}
    ]}"#;
    let servers = vec![start_plain_shard(0, 2), start_faulted_shard(1, 2, plan)];
    let sc = ShardCoordinator::connect(fleet_cfg(&servers, 100)).unwrap();

    let mut rng = Pcg64::new(0x0bad_cafe);
    let (n, t, band) = (9usize, 5usize, 1usize);
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(3)).collect();
    let key = register_corpus(&sc, &series, &labels, band);
    std::thread::sleep(Duration::from_millis(50));

    let front = FrontServer::start(Arc::clone(&sc), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&front.addr).unwrap();
    let q1: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
    let q2: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
    let x = |q: &[f64]| Json::arr(q.iter().copied().map(Json::num));

    let script = vec![
        Json::obj(vec![
            ("op", Json::str("search")),
            ("index", Json::num(key as f64)),
            ("k", Json::num(3.0)),
            ("x", x(&q1)),
        ]),
        Json::obj(vec![
            ("op", Json::str("search")),
            ("index", Json::num(key as f64)),
            ("k", Json::num(3.0)),
            ("x", x(&q1)),
            ("allow_partial", Json::Bool(true)),
        ]),
        Json::obj(vec![
            ("op", Json::str("batch_search")),
            ("index", Json::num(key as f64)),
            ("k", Json::num(2.0)),
            ("xs", Json::arr(vec![x(&q1), x(&q2)])),
            ("allow_partial", Json::Bool(true)),
        ]),
        Json::obj(vec![
            ("op", Json::str("search")),
            ("index", Json::num(key as f64)),
            ("k", Json::num(5.0)),
            ("x", x(&q2)),
            ("allow_partial", Json::Bool(true)),
        ]),
    ];
    script
        .iter()
        .map(|req| project(&client.call(req).unwrap()))
        .collect()
}

/// Acceptance criterion (c): the same fault plan and seed against the
/// same request script reproduce the same reply sequence, byte for byte
/// (modulo the free-text error message carrying an ephemeral port).
#[test]
fn same_plan_and_seed_reproduce_the_reply_sequence() {
    let run1 = chaos_script_run();
    let run2 = chaos_script_run();
    assert_eq!(run1.len(), 4);
    // sanity on shape before equality: typed failure, then flagged partials
    assert!(run1[0].contains(r#""code":"unavailable""#), "{}", run1[0]);
    for r in &run1[1..] {
        assert!(r.contains(r#""ok":true"#), "{r}");
        assert!(r.contains(r#""partial""#), "{r}");
        assert!(r.contains(r#""missing":[1]"#), "{r}");
    }
    assert_eq!(run1, run2);
}

// ---------------------------------------------------------------------------
// 5. transient faults self-heal through the inline retry
// ---------------------------------------------------------------------------

/// A single garbled line and a single torn (mid-line) reply each kill
/// one connection generation; the fan-out's inline reconnect-retry heals
/// both within the same request — full exact answers, zero partial or
/// failed replies, and a closed breaker throughout.
#[test]
fn garbled_and_torn_replies_self_heal_via_retry() {
    // shard 1 reply timeline: 0 verify, 1 register, 2 search A
    // (garbled), 3 verify (retry), 4 search A again, 5 search B (torn),
    // 6 verify (retry), 7 search B again
    let plan = r#"{"seed": 17, "rules": [
        {"shard": 1, "kind": "garble_line", "from": 2, "count": 1},
        {"shard": 1, "kind": "drop_mid_reply", "from": 5, "count": 1}
    ]}"#;
    let servers = vec![start_plain_shard(0, 2), start_faulted_shard(1, 2, plan)];
    let sc = ShardCoordinator::connect(fleet_cfg(&servers, 100)).unwrap();

    let mut rng = Pcg64::new(0xfa17_0005);
    let (n, t, band, k) = (8usize, 6usize, 1usize, 3usize);
    let series = random_series(&mut rng, n, t);
    let labels: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
    let key = register_corpus(&sc, &series, &labels, band);
    let single = SearchEngine::new(
        Arc::new(Index::build(&labeled(&series, &labels), band, 2)),
        Cascade::default(),
    );

    for round in 0..2 {
        let query: Vec<f64> = (0..t).map(|_| rng.range(-2.0, 2.0)).collect();
        let out = sc.search(key, &query, k, None).unwrap();
        assert_eq!(out.shards_ok, 2, "round {round}");
        assert!(out.missing.is_empty(), "round {round}");
        let want = single.knn_values(&query, k).neighbors;
        assert_eq!(out.neighbors.len(), want.len(), "round {round}");
        for (g, w) in out.neighbors.iter().zip(&want) {
            assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "round {round}");
            assert_eq!(g.global_idx, w.train_idx, "round {round}");
        }
    }

    let snap = sc.metrics();
    assert_eq!(snap.partial_failures, 0, "{}", snap.report());
    assert_eq!(snap.partial_replies, 0, "{}", snap.report());
    assert!(snap.shards[1].errors >= 2, "{}", snap.report());
    assert!(snap.shards[1].reconnects >= 2, "{}", snap.report());
    assert_eq!(sc.breaker_states(), vec!["closed", "closed"]);
}
