//! Dirty-workspace determinism: every `*_into` / `*_with` kernel must
//! be bit-identical (`f64::to_bits`) to its allocating counterpart no
//! matter what ran in the workspace before — THE invariant that makes
//! per-worker workspace reuse in `pool::par_map_ws` sound.
//!
//! Every test interleaves calls of different lengths, bands and grids
//! through one shared workspace, and deliberately dirties it with a
//! *different* kernel between the call under test and its oracle.

use spdtw::data::splits::from_pairs;
use spdtw::data::TimeSeries;
use spdtw::measures::dtw::{
    dtw_banded, dtw_banded_into, dtw_path_into, dtw_with_path, BandedDtw,
};
use spdtw::measures::itakura::ItakuraDtw;
use spdtw::measures::kga::Kga;
use spdtw::measures::krdtw::{Krdtw, KrdtwDist};
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::workspace::DpWorkspace;
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::search::early::{dtw_banded_ea, dtw_banded_ea_into, spdtw_ea, spdtw_ea_into};
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::sparse::LocMatrix;
use spdtw::util::rng::Pcg64;
use std::sync::Arc;

fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
    (0..t).map(|_| rng.normal()).collect()
}

/// Clobber every scratch buffer with a size and fill the next kernel
/// must not be able to observe.
fn dirty(ws: &mut DpWorkspace, rng: &mut Pcg64) {
    let t = 1 + rng.below(97);
    ws.rows(t, -123.456);
    ws.pair_rows(t, (3.25, -7.5));
    ws.entries.clear();
    ws.entries.resize(t * 2, 1e9);
    ws.pair_entries.clear();
    ws.pair_entries.resize(t, (2.0, 4.0));
    ws.local_ls.clear();
    ws.local_ls.resize(t, 0.125);
    ws.matrix.clear();
    ws.matrix.resize(t * 3, -1.0);
    ws.query.clear();
    ws.query.resize(t, 42.0);
    ws.lbs.clear();
    ws.lbs.resize(t, -1.0);
    ws.order.clear();
    ws.order.extend(0..t);
    ws.top.clear();
    ws.top.push((-5.0, 9999));
    ws.dists.clear();
    ws.dists.push((7.0, 1));
    ws.lane_row_a.clear();
    ws.lane_row_a.resize(t * 4, -9.0);
    ws.lane_row_b.clear();
    ws.lane_row_b.resize(t * 4, 9.0);
    ws.lane_vals.clear();
    ws.lane_vals.resize(t * 8, 0.5);
    ws.lane_entries.clear();
    ws.lane_entries.resize(t * 5, -2.5);
}

#[test]
fn dtw_banded_into_bit_identical_under_interleaving() {
    let mut rng = Pcg64::new(0x5ee1);
    let mut ws = DpWorkspace::new();
    for case in 0..40 {
        let tx = 2 + rng.below(48);
        let ty = 2 + rng.below(48);
        let x = rand_vec(&mut rng, tx);
        let y = rand_vec(&mut rng, ty);
        for band in [0usize, 1, 5, 17, usize::MAX] {
            dirty(&mut ws, &mut rng);
            let a = dtw_banded_into(&mut ws, &x, &y, band);
            let b = dtw_banded(&x, &y, band);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "case {case} band {band}");
            assert_eq!(a.visited_cells, b.visited_cells);
        }
    }
}

#[test]
fn dtw_banded_ea_into_bit_identical_for_all_bounds() {
    let mut rng = Pcg64::new(0xea7);
    let mut ws = DpWorkspace::new();
    for _ in 0..30 {
        let t = 4 + rng.below(40);
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let exact = dtw_banded(&x, &y, usize::MAX);
        for frac in [0.0, 0.3, 0.8, 1.5, f64::INFINITY] {
            let ub = frac * exact.value;
            dirty(&mut ws, &mut rng);
            let a = dtw_banded_ea_into(&mut ws, &x, &y, usize::MAX, ub);
            let b = dtw_banded_ea(&x, &y, usize::MAX, ub);
            assert_eq!(a.visited, b.visited);
            assert_eq!(a.value.map(f64::to_bits), b.value.map(f64::to_bits));
        }
    }
}

#[test]
fn spdtw_eval_with_bit_identical_across_grids() {
    let mut rng = Pcg64::new(0x5bd);
    let mut ws = DpWorkspace::new();
    for t in [3usize, 9, 21, 33] {
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);
        let mut triples = vec![(0usize, 0usize, 1.0f64), (t - 1, t - 1, 1.0)];
        for i in 0..t {
            for j in 0..t {
                if rng.f64() < 0.4 {
                    triples.push((i, j, rng.range(0.5, 3.0)));
                }
            }
        }
        for loc in [LocMatrix::from_triples(t, triples), LocMatrix::corridor(t, 2)] {
            let sp = SpDtw::new(loc.clone());
            dirty(&mut ws, &mut rng);
            let a = sp.eval_with(&mut ws, &x, &y);
            let b = sp.eval(&x, &y);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "t={t}");
            assert_eq!(a.visited_cells, b.visited_cells);

            let ub = 0.7 * b.value;
            dirty(&mut ws, &mut rng);
            let ea_ws = spdtw_ea_into(&mut ws, &loc, &x, &y, ub);
            let ea = spdtw_ea(&loc, &x, &y, ub);
            assert_eq!(ea_ws.visited, ea.visited);
            assert_eq!(ea_ws.value.map(f64::to_bits), ea.value.map(f64::to_bits));
        }
    }
}

#[test]
fn kernel_log_with_bit_identical_under_interleaving() {
    let mut rng = Pcg64::new(0x10c);
    let mut ws = DpWorkspace::new();
    for t in [2usize, 8, 19, 40] {
        let x = rand_vec(&mut rng, t);
        let y = rand_vec(&mut rng, t);

        dirty(&mut ws, &mut rng);
        let kr = Krdtw::new(0.9);
        assert_eq!(
            kr.log_kernel_with(&mut ws, &x, &y).value.to_bits(),
            kr.log_kernel(&x, &y).value.to_bits(),
            "Krdtw t={t}"
        );

        dirty(&mut ws, &mut rng);
        let krb = Krdtw::with_band(1.3, 3);
        assert_eq!(
            krb.log_kernel_with(&mut ws, &x, &y).value.to_bits(),
            krb.log_kernel(&x, &y).value.to_bits(),
            "Krdtw_sc t={t}"
        );

        dirty(&mut ws, &mut rng);
        let spk = SpKrdtw::new(LocMatrix::corridor(t, 2), 0.7);
        assert_eq!(
            spk.log_kernel_with(&mut ws, &x, &y).value.to_bits(),
            spk.log_kernel(&x, &y).value.to_bits(),
            "SP-Krdtw t={t}"
        );

        dirty(&mut ws, &mut rng);
        let kga = Kga::new(1.1);
        assert_eq!(
            kga.log_kernel_with(&mut ws, &x, &y).value.to_bits(),
            kga.log_kernel(&x, &y).value.to_bits(),
            "Kga t={t}"
        );
    }
}

#[test]
fn dist_with_matches_dist_for_every_dp_measure() {
    let mut rng = Pcg64::new(0xd157);
    let mut ws = DpWorkspace::new();
    for t in [5usize, 16, 31] {
        let x = TimeSeries::new(0, rand_vec(&mut rng, t));
        let y = TimeSeries::new(1, rand_vec(&mut rng, t));
        let measures: Vec<Box<dyn Measure>> = vec![
            Box::new(spdtw::measures::dtw::Dtw),
            Box::new(BandedDtw(3)),
            Box::new(spdtw::measures::sakoe_chiba::SakoeChibaDtw::new(10.0)),
            Box::new(ItakuraDtw),
            Box::new(SpDtw::new(LocMatrix::corridor(t, 2))),
            Box::new(KrdtwDist::new(Krdtw::new(0.8))),
            Box::new(spdtw::measures::spkrdtw::SpKrdtwDist::new(SpKrdtw::new(
                LocMatrix::corridor(t, 2),
                0.8,
            ))),
        ];
        for m in &measures {
            dirty(&mut ws, &mut rng);
            let a = m.dist_with(&mut ws, &x, &y);
            let b = m.dist(&x, &y);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{} t={t}", m.name());
            assert_eq!(a.visited_cells, b.visited_cells, "{} t={t}", m.name());
        }
    }
}

#[test]
fn path_backtracking_into_matches_allocating() {
    let mut rng = Pcg64::new(0xbac);
    let mut ws = DpWorkspace::new();
    for _ in 0..20 {
        let tx = 2 + rng.below(24);
        let ty = 2 + rng.below(24);
        let x = rand_vec(&mut rng, tx);
        let y = rand_vec(&mut rng, ty);
        dirty(&mut ws, &mut rng);
        let mut path = Vec::new();
        let a = dtw_path_into(&mut ws, &x, &y, &mut path);
        let (b, want_path) = dtw_with_path(&x, &y);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(path, want_path);
    }
}

#[test]
fn engine_knn_with_matches_fresh_workspace_bitwise() {
    let mut rng = Pcg64::new(0xe26);
    let train = from_pairs(
        (0..12)
            .map(|i| (i % 3, rand_vec(&mut rng, 20)))
            .collect(),
    );
    let mut shared = DpWorkspace::new();
    for (idx, cascade) in [
        (Arc::new(Index::build(&train, 4, 1)), Cascade::default()),
        (Arc::new(Index::build(&train, 4, 1)), Cascade::none()),
        (
            Arc::new(Index::build_spdtw(
                &train,
                Arc::new(LocMatrix::corridor(20, 4)),
                1,
            )),
            Cascade::default(),
        ),
        (
            Arc::new(Index::build_znormalized(&train, 4, 1)),
            Cascade::default(),
        ),
    ] {
        let eng = SearchEngine::new(idx, cascade);
        for k in [1usize, 3] {
            for _ in 0..6 {
                let q = rand_vec(&mut rng, 20);
                dirty(&mut shared, &mut rng);
                let a = eng.knn_values_with(&mut shared, &q, k);
                let b = eng.knn_values(&q, k);
                assert_eq!(a.neighbors.len(), b.neighbors.len());
                for (na, nb) in a.neighbors.iter().zip(&b.neighbors) {
                    assert_eq!(na.dist.to_bits(), nb.dist.to_bits());
                    assert_eq!(na.train_idx, nb.train_idx);
                    assert_eq!(na.label, nb.label);
                }
                assert_eq!(a.stats.dp_cells, b.stats.dp_cells);
                assert_eq!(a.stats.lb_cells, b.stats.lb_cells);
            }
        }
    }
}

#[test]
fn pool_parallelism_is_bit_invariant_for_knn_and_gram() {
    use spdtw::classify::gram::train_gram;
    use spdtw::classify::nn::classify_knn;

    let mut rng = Pcg64::new(0x90a);
    let train = from_pairs(
        (0..10)
            .map(|i| (i % 2, rand_vec(&mut rng, 16)))
            .collect(),
    );
    let test = from_pairs(
        (0..8)
            .map(|i| (i % 2, rand_vec(&mut rng, 16)))
            .collect(),
    );
    // serial TLS-workspace path vs persistent-pool per-worker path
    let a = classify_knn(&BandedDtw(4), &train, &test, 3, 1);
    let b = classify_knn(&BandedDtw(4), &train, &test, 3, 4);
    assert_eq!(a.error_rate, b.error_rate);
    assert_eq!(a.visited_cells, b.visited_cells);

    let g1 = train_gram(&Krdtw::new(1.0), &train, 1);
    let g4 = train_gram(&Krdtw::new(1.0), &train, 4);
    assert_eq!(g1.visited_cells, g4.visited_cells);
    let bits1: Vec<u64> = g1.data.iter().map(|v| v.to_bits()).collect();
    let bits4: Vec<u64> = g4.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(bits1, bits4);
}

#[test]
fn kernel_measure_log_k_with_matches_log_k() {
    let mut rng = Pcg64::new(0x3a1);
    let mut ws = DpWorkspace::new();
    let x = TimeSeries::new(0, rand_vec(&mut rng, 18));
    let y = TimeSeries::new(1, rand_vec(&mut rng, 18));
    let kernels: Vec<Box<dyn KernelMeasure>> = vec![
        Box::new(Krdtw::new(0.6)),
        Box::new(Krdtw::with_band(0.6, 4)),
        Box::new(SpKrdtw::new(LocMatrix::corridor(18, 3), 0.6)),
        Box::new(Kga::new(0.6)),
    ];
    for kern in &kernels {
        dirty(&mut ws, &mut rng);
        let a = kern.log_k_with(&mut ws, &x, &y);
        let b = kern.log_k(&x, &y);
        assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", KernelMeasure::name(&**kern));
        assert_eq!(a.visited_cells, b.visited_cells);
    }
}
