//! Coordinator integration: routing, batching, backend parity, metrics,
//! TCP server — with and without the PJRT engine.

use std::path::PathBuf;
use std::sync::Arc;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::request::Backend;
use spdtw::coordinator::server::{Client, Server};
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::data::TimeSeries;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::runtime::PjrtRuntime;
use spdtw::sparse::LocMatrix;
use spdtw::util::json::Json;
use spdtw::util::rng::Pcg64;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn rand_series(rng: &mut Pcg64, t: usize) -> TimeSeries {
    TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect())
}

#[test]
fn pjrt_backend_parity_spdtw() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let cfg = CoordinatorConfig {
        prefer_pjrt: true,
        flush_us: 500,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, Some(rt.handle())).unwrap();
    let t = 60;
    let loc = LocMatrix::corridor(t, 8);
    let key = coord.register_grid(loc.clone()).unwrap();
    let mut rng = Pcg64::new(5);
    let pairs: Vec<(TimeSeries, TimeSeries)> = (0..50)
        .map(|_| (rand_series(&mut rng, t), rand_series(&mut rng, t)))
        .collect();
    let tickets: Vec<_> = pairs
        .iter()
        .map(|(x, y)| coord.submit_spdtw(key, x, y).unwrap())
        .collect();
    coord.flush();
    let sp = SpDtw::new(loc);
    let mut pjrt_seen = 0;
    for (ticket, (x, y)) in tickets.into_iter().zip(&pairs) {
        let r = ticket.wait().unwrap();
        if r.backend == Backend::Pjrt {
            pjrt_seen += 1;
        }
        let native = sp.dist(x, y).value;
        let rel = (r.value - native).abs() / native.max(1e-9);
        assert!(rel < 1e-3, "pjrt={} native={native}", r.value);
    }
    assert!(pjrt_seen > 0, "expected pjrt routing with prefer_pjrt");
    let snap = coord.metrics();
    assert_eq!(snap.completed, 50);
    assert!(snap.batches >= 1);
}

#[test]
fn pjrt_backend_parity_spkrdtw() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let cfg = CoordinatorConfig {
        prefer_pjrt: true,
        flush_us: 500,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, Some(rt.handle())).unwrap();
    let t = 60;
    let nu = 0.3;
    let loc = LocMatrix::corridor(t, 10);
    let key = coord.register_grid(loc.clone()).unwrap();
    let mut rng = Pcg64::new(6);
    let pairs: Vec<(TimeSeries, TimeSeries)> = (0..40)
        .map(|_| (rand_series(&mut rng, t), rand_series(&mut rng, t)))
        .collect();
    let tickets: Vec<_> = pairs
        .iter()
        .map(|(x, y)| coord.submit_spkrdtw(key, nu, x, y).unwrap())
        .collect();
    coord.flush();
    let spk = SpKrdtw::new(loc, nu);
    for (ticket, (x, y)) in tickets.into_iter().zip(&pairs) {
        let r = ticket.wait().unwrap();
        let native = spk.log_k(x, y).value;
        assert!(
            (r.value - native).abs() < 1e-8,
            "pjrt={} native={native}",
            r.value
        );
    }
}

#[test]
fn unknown_length_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let cfg = CoordinatorConfig {
        prefer_pjrt: true,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, Some(rt.handle())).unwrap();
    let t = 73; // no artifact bucket
    let key = coord.register_grid(LocMatrix::corridor(t, 3)).unwrap();
    let mut rng = Pcg64::new(7);
    let x = rand_series(&mut rng, t);
    let y = rand_series(&mut rng, t);
    let r = coord.submit_spdtw(key, &x, &y).unwrap().wait().unwrap();
    assert_eq!(r.backend, Backend::Native);
    coord.wait_native_idle();
    assert!(coord.metrics().native_jobs >= 1);
}

#[test]
fn partial_batches_flush_by_timeout() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let cfg = CoordinatorConfig {
        prefer_pjrt: true,
        flush_us: 1_000, // 1ms
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, Some(rt.handle())).unwrap();
    let t = 60;
    let key = coord.register_grid(LocMatrix::full(t)).unwrap();
    let mut rng = Pcg64::new(8);
    let x = rand_series(&mut rng, t);
    let y = rand_series(&mut rng, t);
    // single job (batch of 32 never fills) — must still complete
    let ticket = coord.submit_spdtw(key, &x, &y).unwrap();
    let r = ticket.wait().unwrap();
    assert_eq!(r.backend, Backend::Pjrt);
    let snap = coord.metrics();
    assert!(snap.padded_slots >= 31, "padded={}", snap.padded_slots);
    assert!(snap.timeout_flushes >= 1);
}

#[test]
fn server_over_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let cfg = CoordinatorConfig {
        prefer_pjrt: true,
        flush_us: 500,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(cfg, Some(rt.handle())).unwrap());
    let mut server = Server::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr).unwrap();

    let reg = client
        .call(&Json::parse(r#"{"op":"register_grid","t":60,"band":5}"#).unwrap())
        .unwrap();
    assert_eq!(reg.get("ok"), Some(&Json::Bool(true)));
    let gid = reg.req_usize("grid").unwrap();

    let mut rng = Pcg64::new(9);
    let x: Vec<String> = (0..60).map(|_| format!("{:.4}", rng.normal())).collect();
    let y: Vec<String> = (0..60).map(|_| format!("{:.4}", rng.normal())).collect();
    let req = format!(
        r#"{{"op":"spdtw","grid":{gid},"x":[{}],"y":[{}]}}"#,
        x.join(","),
        y.join(",")
    );
    let resp = client.call(&Json::parse(&req).unwrap()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(resp.req_str("backend").unwrap(), "pjrt");
    assert!(resp.req_f64("value").unwrap() > 0.0);
    server.stop();
}

#[test]
fn native_only_coordinator_handles_concurrent_load() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None).unwrap();
    let t = 40;
    let key = coord.register_grid(LocMatrix::corridor(t, 5)).unwrap();
    let mut rng = Pcg64::new(10);
    let tickets: Vec<_> = (0..200)
        .map(|_| {
            let x = rand_series(&mut rng, t);
            let y = rand_series(&mut rng, t);
            coord.submit_spdtw(key, &x, &y).unwrap()
        })
        .collect();
    let mut ok = 0;
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.value.is_finite());
        ok += 1;
    }
    assert_eq!(ok, 200);
    let snap = coord.metrics();
    assert_eq!(snap.completed, 200);
    assert_eq!(snap.failed, 0);
}
