//! End-to-end pipeline integration: the experiment runner over a small
//! dataset slice, report writing, Fig-panels, config round-trips.

use spdtw::config::ExperimentConfig;
use spdtw::experiments::{self, runner};

fn cfg(tag: &str, datasets: &[&str]) -> ExperimentConfig {
    ExperimentConfig {
        max_train: 10,
        max_test: 8,
        threads: 8,
        datasets: datasets.iter().map(|s| s.to_string()).collect(),
        out_dir: std::env::temp_dir().join(format!("spdtw_pipe_{tag}_{}", std::process::id())),
        ..Default::default()
    }
}

#[test]
fn experiment_all_on_tiny_slice() {
    let cfg = cfg("all", &["CBF", "SyntheticControl"]);
    experiments::run("all", &cfg).unwrap();
    for f in [
        "table1.md",
        "table2.md",
        "table2.json",
        "table3.md",
        "table4.md",
        "table5.md",
        "table6.md",
        "fig4.md",
    ] {
        assert!(cfg.out_dir.join(f).exists(), "{f} missing");
    }
    for fig in ["fig5", "fig6", "fig7", "fig8"] {
        assert!(cfg.out_dir.join(fig).join("panels.md").exists(), "{fig}");
    }
    // table2.md has one row per dataset + mean rank
    let t2 = std::fs::read_to_string(cfg.out_dir.join("table2.md")).unwrap();
    assert!(t2.contains("CBF") && t2.contains("SyntheticControl") && t2.contains("Mean rank"));
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn runner_is_deterministic_given_seed() {
    let c = cfg("det", &["Gun-Point"]);
    let a = runner::evaluate_dataset(&c, "Gun-Point", false).unwrap();
    let b = runner::evaluate_dataset(&c, "Gun-Point", false).unwrap();
    assert_eq!(a.err_1nn, b.err_1nn);
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.cells, b.cells);
}

#[test]
fn different_seeds_change_data_not_structure() {
    let mut c1 = cfg("seed1", &["CBF"]);
    c1.seed = 1;
    let mut c2 = cfg("seed2", &["CBF"]);
    c2.seed = 2;
    let a = runner::evaluate_dataset(&c1, "CBF", false).unwrap();
    let b = runner::evaluate_dataset(&c2, "CBF", false).unwrap();
    assert_eq!(a.t, b.t);
    assert_eq!(a.n_train, b.n_train);
    // columns present either way
    assert_eq!(
        a.err_1nn.keys().collect::<Vec<_>>(),
        b.err_1nn.keys().collect::<Vec<_>>()
    );
}

#[test]
fn table6_shape_holds_on_slice() {
    // SP methods must report fewer cells than full DTW on every dataset
    // (the paper's average speed-up claim, scaled down).
    let c = cfg("t6", &["CBF", "SyntheticControl", "Gun-Point"]);
    for name in ["CBF", "SyntheticControl", "Gun-Point"] {
        let ev = runner::evaluate_dataset(&c, name, false).unwrap();
        let full = ev.cells["DTW"];
        assert!(ev.cells["SP-DTW"] < full, "{name}: SP-DTW not sparser");
        assert!(ev.cells["SP-Krdtw"] < full, "{name}: SP-Krdtw not sparser");
        let speedup = 100.0 * (1.0 - ev.cells["SP-DTW"] as f64 / full as f64);
        assert!(speedup > 10.0, "{name}: speed-up only {speedup:.1}%");
    }
}
