//! Property tests for the streaming subsystem (`spdtw::stream`): the
//! sliding Lemire envelope must be *bit-identical* to a from-scratch
//! `envelope` rebuild at every step (including forced ties and ±0.0),
//! the incremental z-norm must track the batch statistics, a streaming
//! monitor's per-window answers — neighbors AND prune counters — must
//! equal a batch search over the same window, and the RWS pre-filter
//! must reach recall@k = 1.0 whenever its candidate budget covers the
//! whole corpus.

use std::sync::Arc;

use spdtw::data::splits::from_pairs;
use spdtw::measures::lb_keogh::envelope;
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::stream::{IncZnorm, RwsConfig, SlidingEnvelope, StreamMonitor};
use spdtw::util::prop::{forall_vec, PropConfig};
use spdtw::util::rng::Pcg64;

/// Feed `stream` through a [`SlidingEnvelope`] of shape `(t, r)` and
/// compare every full window's staged envelope bitwise against the
/// batch rebuild.
fn sliding_matches_batch(stream: &[f64], t: usize, r: usize) -> bool {
    if stream.len() < t {
        return true;
    }
    let mut env = SlidingEnvelope::new(t, r);
    let mut ring = vec![0.0; t];
    let mut window = vec![0.0; t];
    let mut upper = Vec::new();
    let mut lower = Vec::new();
    for (p, &v) in stream.iter().enumerate() {
        ring[p % t] = v;
        env.push(p, &ring);
        if p + 1 < t {
            continue;
        }
        let start = p + 1 - t;
        for i in 0..t {
            window[i] = ring[(start + i) % t];
        }
        env.stage_into(p, &window, &mut upper, &mut lower);
        let (bu, bl) = envelope(&window, r.min(t - 1));
        for i in 0..t {
            if upper[i].to_bits() != bu[i].to_bits() || lower[i].to_bits() != bl[i].to_bits() {
                return false;
            }
        }
    }
    true
}

#[test]
fn prop_sliding_envelope_bitwise_matches_batch() {
    let cfg = PropConfig::default();
    forall_vec(&cfg, 8, 80, 4.0, |xs| {
        // shapes derived from the case so radii sweep the non-degenerate
        // (2r < t), boundary and degenerate (2r >= t) regimes
        let t = 2 + xs.len() % 13;
        (0..=t).step_by(1 + t / 4).all(|r| sliding_matches_batch(xs, t, r))
    });
}

#[test]
fn prop_sliding_envelope_survives_ties_and_signed_zero() {
    let cfg = PropConfig::default();
    forall_vec(&cfg, 8, 64, 4.0, |xs| {
        // quantize onto a 5-value grid containing both zero signs:
        // repeated extrema (ties) now occur in nearly every window, the
        // regime where a wrong tie-break picks a different bit pattern
        let grid: Vec<f64> = xs
            .iter()
            .map(|&v| match (v.round() as i64).clamp(-2, 2) {
                -2 => -1.0,
                -1 => -0.0,
                0 => 0.0,
                1 => 1.0,
                _ => 2.0,
            })
            .collect();
        let t = 3 + xs.len() % 9;
        [0, 1, t / 2, t].iter().all(|&r| sliding_matches_batch(&grid, t, r))
    });
}

#[test]
fn prop_inc_znorm_tracks_batch_statistics() {
    let cfg = PropConfig::default();
    forall_vec(&cfg, 4, 72, 5.0, |xs| {
        let t = 2 + xs.len() % 11;
        let mut inc = IncZnorm::new(t);
        for (p, &v) in xs.iter().enumerate() {
            let evicted = if p >= t { Some(xs[p - t]) } else { None };
            inc.push(v, evicted);
            let lo = (p + 1).saturating_sub(t);
            let win = &xs[lo..=p];
            let n = win.len() as f64;
            let mean = win.iter().sum::<f64>() / n;
            let var = (win.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n).max(0.0);
            if (inc.mean() - mean).abs() > 1e-9 || (inc.std() - var.sqrt()).abs() > 1e-8 {
                return false;
            }
        }
        true
    });
}

/// A small deterministic corpus of window-length series (label = i % 2).
fn tiny_index(t: usize, n: usize, seed: u64, znorm: bool) -> Arc<Index> {
    let mut rng = Pcg64::new(seed);
    let pairs: Vec<(usize, Vec<f64>)> = (0..n)
        .map(|i| (i % 2, (0..t).map(|_| rng.normal()).collect()))
        .collect();
    let set = from_pairs(pairs);
    let band = (t / 4).max(1);
    Arc::new(if znorm {
        Index::build_znormalized(&set, band, 1)
    } else {
        Index::build(&set, band, 1)
    })
}

/// Every reported window must equal a batch `knn_values` over the same
/// window — neighbor bits AND the full prune-counter accounting.
fn monitor_matches_batch(stream: &[f64], index: &Arc<Index>, k: usize) -> bool {
    let t = index.t;
    if stream.len() < t {
        return true;
    }
    let eng = SearchEngine::new(Arc::clone(index), Cascade::default());
    let mut mon = StreamMonitor::new(SearchEngine::new(Arc::clone(index), Cascade::default()), k, None)
        .unwrap();
    for (p, &v) in stream.iter().enumerate() {
        let rep = mon.push(v).unwrap();
        if p + 1 < t {
            if rep.is_some() {
                return false;
            }
            continue;
        }
        let rep = match rep {
            Some(r) => r,
            None => return false,
        };
        let want = eng.knn_values(&stream[p + 1 - t..=p], k);
        if rep.approx
            || rep.window_start != (p + 1 - t) as u64
            || rep.neighbors.len() != want.neighbors.len()
            || rep.stats != want.stats
        {
            return false;
        }
        for (g, w) in rep.neighbors.iter().zip(&want.neighbors) {
            if g.train_idx != w.train_idx || g.dist.to_bits() != w.dist.to_bits() {
                return false;
            }
        }
    }
    true
}

#[test]
fn prop_stream_monitor_bitwise_matches_batch_search() {
    let cfg = PropConfig::default();
    let raw = tiny_index(9, 7, 0xfeed, false);
    let znormed = tiny_index(9, 7, 0xfeed, true);
    forall_vec(&cfg, 9, 60, 3.0, |xs| {
        monitor_matches_batch(xs, &raw, 3) && monitor_matches_batch(xs, &znormed, 3)
    });
}

#[test]
fn prop_rws_full_budget_has_perfect_recall() {
    let cfg = PropConfig::default();
    let index = tiny_index(8, 6, 0xbead, false);
    let rws = RwsConfig {
        d: 4,
        candidates: index.len(), // budget covers the corpus: exact by construction
        audit_every: 1,
        ..RwsConfig::default()
    };
    forall_vec(&cfg, 8, 48, 3.0, |xs| {
        let eng = SearchEngine::new(Arc::clone(&index), Cascade::default());
        let mut mon =
            StreamMonitor::new(SearchEngine::new(Arc::clone(&index), Cascade::default()), 2, Some(rws))
                .unwrap();
        for (p, &v) in xs.iter().enumerate() {
            if let Some(rep) = mon.push(v).unwrap() {
                if !rep.approx || rep.recall != Some(1.0) {
                    return false;
                }
                let want = eng.knn_values(&xs[p + 1 - index.t..=p], 2);
                for (g, w) in rep.neighbors.iter().zip(&want.neighbors) {
                    if g.train_idx != w.train_idx || g.dist.to_bits() != w.dist.to_bits() {
                        return false;
                    }
                }
            }
        }
        mon.stats().recall().map_or(true, |r| r == 1.0)
    });
}
