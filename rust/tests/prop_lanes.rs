//! Lane-kernel exactness properties: the lane-batched DP kernels and
//! the lane-group engine schedule must be bit-identical (`f64::to_bits`)
//! to their scalar counterparts — per lane at the kernel level, and on
//! the final `(dist, train idx)` top-k at the engine level — across
//! interleaved lengths, bands, grids (degenerates included), lane
//! counts, ragged tails, and deliberately dirtied workspaces.

use spdtw::data::splits::from_pairs;
use spdtw::measures::dtw::dtw_banded;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::workspace::DpWorkspace;
use spdtw::search::early::{dtw_banded_ea_into, spdtw_ea_into, EaResult};
use spdtw::search::lanes::{
    dtw_banded_ea_lanes_into, pack_candidate_major, spdtw_ea_lanes_into, MAX_LANES,
};
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::sparse::LocMatrix;
use spdtw::util::rng::Pcg64;
use std::sync::Arc;

fn rand_vec(rng: &mut Pcg64, t: usize) -> Vec<f64> {
    (0..t).map(|_| rng.normal()).collect()
}

fn blank() -> EaResult {
    EaResult {
        value: None,
        visited: 0,
    }
}

/// Clobber every scratch buffer — the lane fields included — with sizes
/// and fills the next kernel must not be able to observe.
fn dirty(ws: &mut DpWorkspace, rng: &mut Pcg64) {
    let t = 1 + rng.below(97);
    ws.rows(t, -123.456);
    ws.entries.clear();
    ws.entries.resize(t * 2, 1e9);
    ws.query.clear();
    ws.query.resize(t, 42.0);
    ws.lane_row_a.clear();
    ws.lane_row_a.resize(t * 4, -9.0);
    ws.lane_row_b.clear();
    ws.lane_row_b.resize(t * 4, 9.0);
    ws.lane_vals.clear();
    ws.lane_vals.resize(t * 8, 0.5);
    ws.lane_entries.clear();
    ws.lane_entries.resize(t * 5, -2.5);
}

fn assert_lanes_match_scalar(out: &[EaResult], scalar: &[EaResult], tag: &str) {
    assert_eq!(out.len(), scalar.len(), "{tag}");
    for (l, (a, b)) in out.iter().zip(scalar).enumerate() {
        assert_eq!(a.visited, b.visited, "{tag} lane {l} visited");
        assert_eq!(
            a.value.map(f64::to_bits),
            b.value.map(f64::to_bits),
            "{tag} lane {l} value"
        );
    }
}

#[test]
fn dtw_lane_kernel_bit_identical_across_matrix() {
    let mut rng = Pcg64::new(0x1a9e);
    let mut ws = DpWorkspace::new();
    let mut sws = DpWorkspace::new();
    for case in 0..60 {
        let tx = 2 + rng.below(40);
        let ty = 2 + rng.below(40);
        let lanes = 1 + rng.below(MAX_LANES);
        let x = rand_vec(&mut rng, tx);
        let cands: Vec<Vec<f64>> = (0..lanes).map(|_| rand_vec(&mut rng, ty)).collect();
        let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
        let band = match case % 4 {
            0 => usize::MAX,
            1 => 1,
            2 => 1 + rng.below(ty),
            _ => ty + tx, // wider than both: also unbounded
        };
        // mixed abandon pressure: disabled, loose, tight, absurd
        let ubs: Vec<f64> = (0..lanes)
            .map(|l| match l % 4 {
                0 => f64::INFINITY,
                1 => 50.0 + rng.normal().abs(),
                2 => 0.5 * rng.normal().abs(),
                _ => 0.0,
            })
            .collect();
        // dirty between the lane call and its scalar oracle
        dirty(&mut ws, &mut rng);
        let mut out = vec![blank(); lanes];
        dtw_banded_ea_lanes_into(&mut ws, &x, &ys, band, &ubs, &mut out);
        let scalar: Vec<EaResult> = (0..lanes)
            .map(|l| {
                dirty(&mut sws, &mut rng);
                dtw_banded_ea_into(&mut sws, &x, ys[l], band, ubs[l])
            })
            .collect();
        assert_lanes_match_scalar(&out, &scalar, &format!("case {case} band {band}"));
    }
}

#[test]
fn spdtw_lane_kernel_bit_identical_incl_degenerate_grids() {
    let mut rng = Pcg64::new(0x2b7d);
    let mut ws = DpWorkspace::new();
    let mut sws = DpWorkspace::new();
    let t = 12;
    let grids = [
        LocMatrix::corridor(t, 2),
        LocMatrix::corridor(t, 5),
        // cornerless: sentinel for every lane, zero DP
        LocMatrix::from_triples(t, (0..t - 1).map(|i| (i, i, 1.0)).collect()),
        // empty middle row, corner present: disconnected but finite
        LocMatrix::from_triples(
            t,
            (0..t)
                .filter(|&i| i != t / 2)
                .flat_map(|i| {
                    let lo = i.saturating_sub(1);
                    let hi = (i + 1).min(t - 1);
                    (lo..=hi).map(move |j| (i, j, 1.0))
                })
                .collect(),
        ),
    ];
    for (gi, loc) in grids.iter().enumerate() {
        for lanes in [1usize, 2, 4, 5, 8] {
            let x = rand_vec(&mut rng, t);
            let cands: Vec<Vec<f64>> = (0..lanes).map(|_| rand_vec(&mut rng, t)).collect();
            let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
            let ubs: Vec<f64> = (0..lanes)
                .map(|l| match l % 3 {
                    0 => f64::INFINITY,
                    1 => 1e25,
                    _ => rng.normal().abs(),
                })
                .collect();
            dirty(&mut ws, &mut rng);
            let mut out = vec![blank(); lanes];
            spdtw_ea_lanes_into(&mut ws, loc, &x, &ys, &ubs, &mut out);
            let scalar: Vec<EaResult> = (0..lanes)
                .map(|l| {
                    dirty(&mut sws, &mut rng);
                    spdtw_ea_into(&mut sws, loc, &x, ys[l], ubs[l])
                })
                .collect();
            assert_lanes_match_scalar(&out, &scalar, &format!("grid {gi} lanes {lanes}"));
        }
    }
}

#[test]
fn lane_kernels_are_deterministic_under_workspace_reuse() {
    // same inputs through one workspace, interleaved with other lane
    // widths and dirt: every repetition must reproduce the first run
    let mut rng = Pcg64::new(0x3c5f);
    let mut ws = DpWorkspace::new();
    let x = rand_vec(&mut rng, 24);
    let cands: Vec<Vec<f64>> = (0..4).map(|_| rand_vec(&mut rng, 24)).collect();
    let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
    let ubs = [f64::INFINITY, 3.0, 0.1, f64::INFINITY];
    let mut first = vec![blank(); 4];
    dtw_banded_ea_lanes_into(&mut ws, &x, &ys, 4, &ubs, &mut first);
    for rep in 0..10 {
        // interleave a different-width call on the same buffers
        let w = 1 + rng.below(MAX_LANES);
        let other: Vec<&[f64]> = (0..w).map(|i| ys[i % ys.len()]).collect();
        let oubs = vec![0.25; w];
        let mut scratch = vec![blank(); w];
        dtw_banded_ea_lanes_into(&mut ws, &x, &other, 7, &oubs, &mut scratch);
        dirty(&mut ws, &mut rng);
        let mut again = vec![blank(); 4];
        dtw_banded_ea_lanes_into(&mut ws, &x, &ys, 4, &ubs, &mut again);
        assert_lanes_match_scalar(&again, &first, &format!("rep {rep}"));
    }
}

#[test]
fn pack_candidate_major_roundtrips() {
    let mut rng = Pcg64::new(0x4d11);
    let mut buf = Vec::new();
    for _ in 0..20 {
        let t = 1 + rng.below(50);
        let lanes = 1 + rng.below(MAX_LANES);
        let cands: Vec<Vec<f64>> = (0..lanes).map(|_| rand_vec(&mut rng, t)).collect();
        let ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
        pack_candidate_major(&ys, &mut buf);
        assert_eq!(buf.len(), t * lanes);
        for (l, c) in cands.iter().enumerate() {
            for (j, &v) in c.iter().enumerate() {
                assert_eq!(buf[j * lanes + l].to_bits(), v.to_bits());
            }
        }
    }
}

/// Brute-force top-k under the engine's (dist, idx) order.
fn brute_topk(idx: &Index, query: &[f64], k: usize) -> Vec<(f64, usize)> {
    let mut all: Vec<(f64, usize)> = (0..idx.len())
        .map(|j| {
            let d = match &idx.loc {
                Some(loc) => SpDtw::from_arc(Arc::clone(loc))
                    .eval(query, &idx.series[j])
                    .value,
                None => dtw_banded(query, &idx.series[j], idx.band).value,
            };
            (d, j)
        })
        .collect();
    all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    all.truncate(k);
    all
}

fn keys(r: &spdtw::search::engine::QueryResult) -> Vec<(u64, usize)> {
    r.neighbors
        .iter()
        .map(|n| (n.dist.to_bits(), n.train_idx))
        .collect()
}

#[test]
fn engine_lane_count_invariance_and_ragged_tails() {
    // train sizes chosen so survivors % lanes != 0 in many configs,
    // including n < lanes (the whole query is one ragged group)
    let mut rng = Pcg64::new(0x5e23);
    for n in [2usize, 5, 7, 11, 26] {
        let t = 4 + rng.below(16);
        let train = from_pairs((0..n).map(|i| (i % 3, rand_vec(&mut rng, t))).collect());
        let band = 1 + rng.below(t);
        let idx = Arc::new(Index::build(&train, band, 1));
        let scalar = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), 1);
        for k in [1usize, 2, n.min(5)] {
            let q = rand_vec(&mut rng, t);
            let want = brute_topk(&idx, &q, k);
            let base = scalar.knn_values(&q, k);
            assert_eq!(
                keys(&base),
                want.iter().map(|&(d, j)| (d.to_bits(), j)).collect::<Vec<_>>(),
                "scalar engine vs brute, n={n} k={k}"
            );
            for lanes in [2usize, 3, 4, 8] {
                let eng = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), lanes);
                let got = eng.knn_values(&q, k);
                assert_eq!(keys(&got), keys(&base), "n={n} k={k} lanes={lanes}");
            }
        }
    }
}

#[test]
fn engine_lane_invariance_holds_for_spdtw_and_ablations() {
    let mut rng = Pcg64::new(0x6f37);
    let t = 10;
    let loc = Arc::new(LocMatrix::corridor(t, 3));
    let train = from_pairs((0..13).map(|i| (i % 2, rand_vec(&mut rng, t))).collect());
    let idx = Arc::new(Index::build_spdtw(&train, loc, 1));
    let cascades = [
        Cascade::default(),
        Cascade {
            early_abandon: false,
            ..Cascade::default()
        },
        Cascade {
            order_by_lb: false,
            ..Cascade::default()
        },
        Cascade::none(),
    ];
    for cas in cascades {
        let scalar = SearchEngine::with_lanes(Arc::clone(&idx), cas, 1);
        for _ in 0..4 {
            let q = rand_vec(&mut rng, t);
            let base = scalar.knn_values(&q, 3);
            let want = brute_topk(&idx, &q, 3);
            assert_eq!(
                keys(&base),
                want.iter().map(|&(d, j)| (d.to_bits(), j)).collect::<Vec<_>>(),
                "{cas:?}"
            );
            for lanes in [4usize, 8] {
                let eng = SearchEngine::with_lanes(Arc::clone(&idx), cas, lanes);
                assert_eq!(keys(&eng.knn_values(&q, 3)), keys(&base), "{cas:?} lanes={lanes}");
            }
        }
    }
}

#[test]
fn engine_tie_breaks_stay_exact_under_lanes() {
    // duplicate candidates force exact distance ties inside one lane
    // group AND across groups: the smaller train index must win at
    // every width
    let base = vec![0.0, 1.0, 0.0, -1.0, 0.5];
    let far = vec![9.0, 9.0, 9.0, 9.0, 9.0];
    let mut pairs = Vec::new();
    for i in 0..10 {
        pairs.push((i, if i % 2 == 0 { base.clone() } else { far.clone() }));
    }
    let train = from_pairs(pairs);
    let idx = Arc::new(Index::build(&train, 2, 1));
    let scalar = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), 1);
    for k in [1usize, 3, 5] {
        let want = brute_topk(&idx, &base, k);
        let a = scalar.knn_values(&base, k);
        for (n, (wd, wj)) in a.neighbors.iter().zip(&want) {
            assert_eq!(n.dist.to_bits(), wd.to_bits());
            assert_eq!(n.train_idx, *wj);
        }
        for lanes in [4usize, 8] {
            let eng = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), lanes);
            let b = eng.knn_values(&base, k);
            assert_eq!(keys(&b), keys(&a), "k={k} lanes={lanes}");
        }
    }
}

#[test]
fn engine_sentinel_ties_stay_exact_under_lanes() {
    // disconnected SP grid: distances tie at sentinel level; the lane
    // schedule must preserve the (dist, idx) winner bit-for-bit
    let loc = Arc::new(LocMatrix::from_triples(
        4,
        vec![(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (3, 3, 1.0)],
    ));
    let train = from_pairs(vec![
        (0, vec![10.0, 10.0, 0.0, 5.0]),
        (1, vec![-3.0, -3.0, 0.0, 5.0]),
        (0, vec![4.0, 4.0, 4.0, 5.0]),
    ]);
    let idx = Arc::new(Index::build_spdtw(&train, loc, 1));
    let q = [-3.0, 0.0, 0.0, 0.0];
    let want = brute_topk(&idx, &q, 2);
    for lanes in [1usize, 2, 4, 8] {
        let eng = SearchEngine::with_lanes(Arc::clone(&idx), Cascade::default(), lanes);
        let got = eng.knn_values(&q, 2);
        assert_eq!(got.neighbors.len(), want.len());
        for (n, (wd, wj)) in got.neighbors.iter().zip(&want) {
            assert_eq!(n.dist.to_bits(), wd.to_bits(), "lanes={lanes}");
            assert_eq!(n.train_idx, *wj, "lanes={lanes}");
        }
    }
}
