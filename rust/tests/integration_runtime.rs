//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! verify numeric parity with the native Rust DPs.  This is the proof
//! that all three layers (Pallas kernel -> JAX graph -> HLO text ->
//! PJRT -> Rust) compose.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use std::path::PathBuf;

use spdtw::data::synthetic;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::{BIG_THRESH, NEG_THRESH};
use spdtw::runtime::{DtwBatch, KrdtwBatch, PjrtRuntime};
use spdtw::sparse::LocMatrix;
use spdtw::util::rng::Pcg64;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn rand_batch(rng: &mut Pcg64, b: usize, t: usize) -> (Vec<f64>, Vec<f64>) {
    let x: Vec<f64> = (0..b * t).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..b * t).map(|_| rng.normal()).collect();
    (x, y)
}

#[test]
fn dtw_artifact_matches_native_full_grid() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let h = rt.handle();
    let info = h.info().unwrap();
    assert!(info.platform.to_lowercase().contains("cpu") || !info.platform.is_empty());

    let t = 60;
    let b = info.dtw_batch(t).expect("T=60 dtw bucket");
    let mut rng = Pcg64::new(1);
    let (x, y) = rand_batch(&mut rng, b, t);

    let loc = LocMatrix::full(t);
    h.register_plane_f32(100, t, loc.pack_weight_plane_f32()).unwrap();
    let out = h
        .run_dtw(DtwBatch {
            t,
            x: x.iter().map(|&v| v as f32).collect(),
            y: y.iter().map(|&v| v as f32).collect(),
            plane_key: 100,
        })
        .unwrap();
    assert_eq!(out.len(), b);

    let sp = SpDtw::new(loc);
    for i in 0..b {
        let native = sp.eval(&x[i * t..(i + 1) * t], &y[i * t..(i + 1) * t]).value;
        let got = out[i] as f64;
        let rel = (got - native).abs() / native.max(1e-6);
        assert!(rel < 1e-3, "pair {i}: pjrt={got} native={native}");
    }
}

#[test]
fn dtw_artifact_matches_native_sparse_grid() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let h = rt.handle();
    let t = 128;
    let b = h.info().unwrap().dtw_batch(t).expect("T=128 bucket");
    let mut rng = Pcg64::new(2);
    let (x, y) = rand_batch(&mut rng, b, t);

    // corridor + varying weights (SP-DTW shape)
    let mut triples: Vec<(usize, usize, f64)> = Vec::new();
    for i in 0..t {
        for j in i.saturating_sub(4)..=(i + 4).min(t - 1) {
            triples.push((i, j, 1.0 + ((i + j) % 3) as f64));
        }
    }
    let loc = LocMatrix::from_triples(t, triples);
    h.register_plane_f32(7, t, loc.pack_weight_plane_f32()).unwrap();
    let out = h
        .run_dtw(DtwBatch {
            t,
            x: x.iter().map(|&v| v as f32).collect(),
            y: y.iter().map(|&v| v as f32).collect(),
            plane_key: 7,
        })
        .unwrap();
    let sp = SpDtw::new(loc);
    for i in 0..b {
        let native = sp.eval(&x[i * t..(i + 1) * t], &y[i * t..(i + 1) * t]).value;
        let got = out[i] as f64;
        if native >= BIG_THRESH {
            assert!(got >= BIG_THRESH / 10.0);
        } else {
            let rel = (got - native).abs() / native.max(1e-6);
            assert!(rel < 1e-3, "pair {i}: pjrt={got} native={native}");
        }
    }
}

#[test]
fn krdtw_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let h = rt.handle();
    let t = 60;
    let b = h.info().unwrap().krdtw_batch(t).expect("krdtw T=60 bucket");
    let mut rng = Pcg64::new(3);
    let (x, y) = rand_batch(&mut rng, b, t);
    let nu = 0.5;

    // full mask == plain Krdtw
    let loc = LocMatrix::full(t);
    h.register_plane_f64(200, t, loc.pack_mask_plane_f64()).unwrap();
    let out = h
        .run_krdtw(KrdtwBatch {
            t,
            x: x.clone(),
            y: y.clone(),
            plane_key: 200,
            nu,
        })
        .unwrap();
    let native = Krdtw::new(nu);
    for i in 0..b {
        let exp = native
            .log_kernel(&x[i * t..(i + 1) * t], &y[i * t..(i + 1) * t])
            .value;
        assert!(
            (out[i] - exp).abs() < 1e-8,
            "pair {i}: pjrt={} native={exp}",
            out[i]
        );
    }

    // sparse mask == SpKrdtw
    let sparse = LocMatrix::corridor(t, 6);
    h.register_plane_f64(201, t, sparse.pack_mask_plane_f64()).unwrap();
    let out = h
        .run_krdtw(KrdtwBatch {
            t,
            x: x.clone(),
            y: y.clone(),
            plane_key: 201,
            nu,
        })
        .unwrap();
    let spk = SpKrdtw::new(sparse, nu);
    for i in 0..b {
        let exp = spk
            .log_kernel(&x[i * t..(i + 1) * t], &y[i * t..(i + 1) * t])
            .value;
        if exp <= NEG_THRESH {
            assert!(out[i] <= NEG_THRESH);
        } else {
            assert!((out[i] - exp).abs() < 1e-8, "pair {i}");
        }
    }
}

#[test]
fn errors_are_reported_not_panicked() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let h = rt.handle();
    // unregistered plane
    let err = h
        .run_dtw(DtwBatch {
            t: 60,
            x: vec![0.0; 32 * 60],
            y: vec![0.0; 32 * 60],
            plane_key: 999,
        })
        .unwrap_err();
    assert!(err.to_string().contains("unregistered"), "{err}");
    // unknown length bucket
    let err = h
        .run_dtw(DtwBatch {
            t: 61,
            x: vec![0.0; 32 * 61],
            y: vec![0.0; 32 * 61],
            plane_key: 999,
        })
        .unwrap_err();
    assert!(err.to_string().contains("no dtw artifact"), "{err}");
    // wrong batch size
    let loc = LocMatrix::full(60);
    h.register_plane_f32(1, 60, loc.pack_weight_plane_f32()).unwrap();
    let err = h
        .run_dtw(DtwBatch {
            t: 60,
            x: vec![0.0; 5 * 60],
            y: vec![0.0; 5 * 60],
            plane_key: 1,
        })
        .unwrap_err();
    assert!(err.to_string().contains("batch"), "{err}");
}

#[test]
fn end_to_end_identical_series_zero_distance() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::start(&dir).unwrap();
    let h = rt.handle();
    let t = 60;
    let b = h.info().unwrap().dtw_batch(t).unwrap();
    let ds = synthetic::generate_scaled("SyntheticControl", 4, b, 1).unwrap();
    let x: Vec<f32> = ds
        .train
        .series
        .iter()
        .cycle()
        .take(b)
        .flat_map(|s| s.values.iter().map(|&v| v as f32))
        .collect();
    let loc = LocMatrix::full(t);
    h.register_plane_f32(3, t, loc.pack_weight_plane_f32()).unwrap();
    let out = h
        .run_dtw(DtwBatch {
            t,
            x: x.clone(),
            y: x,
            plane_key: 3,
        })
        .unwrap();
    for v in out {
        assert!(v.abs() < 1e-4, "self-distance {v}");
    }
}
