//! Table VI regeneration as a bench: per-dataset visited cells AND
//! measured wall-clock for DTW vs DTW_sc vs SP-DTW vs SP-Krdtw, showing
//! that the cell-count speed-up translates into real time.
//!
//! `SPDTW_BENCH_DATASETS=a,b,c cargo bench --bench bench_table6`
//! defaults to a representative slice of Table I.

use spdtw::config::ExperimentConfig;
use spdtw::data::synthetic;
use spdtw::measures::dtw::Dtw;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::sakoe_chiba::{band_cells, SakoeChibaDtw};
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::tuning;
use spdtw::util::bench::Bench;

fn main() {
    let datasets: Vec<String> = std::env::var("SPDTW_BENCH_DATASETS")
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|_| {
            ["SyntheticControl", "CBF", "Gun-Point", "ECGFiveDays", "Wine", "Adiac"]
                .iter()
                .map(|s| s.to_string())
                .collect()
        });
    let cfg = ExperimentConfig::default();
    println!(
        "{:<18}{:>10}{:>10}{:>8}{:>10}{:>8}{:>10}{:>8}  (cells per comparison, S% = speed-up)",
        "dataset", "DTW", "SC", "S%", "SP-DTW", "S%", "SP-Krdtw", "S%"
    );

    for name in &datasets {
        let ds = match synthetic::generate_scaled(name, cfg.seed, 24, 8) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skip {name}: {e}");
                continue;
            }
        };
        let t = ds.series_len();
        let grid = learn_occupancy_grid(&ds.train, cfg.threads);
        let (band_pct, _) = tuning::tune_band_pct(&ds.train, &tuning::band_pct_grid(), cfg.threads);
        let (theta, _) =
            tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), cfg.threads);
        let sc = SakoeChibaDtw::new(band_pct);
        let loc_w = grid.threshold(theta).to_loc(1.0);
        let loc_m = grid.threshold(theta).to_loc_mask();

        let full = (t * t) as f64;
        let c_sc = band_cells(t, sc.band_for(t)) as f64;
        let c_sp = loc_w.nnz() as f64;
        let c_spk = loc_m.nnz() as f64;
        println!(
            "{:<18}{:>10}{:>10}{:>8.1}{:>10}{:>8.1}{:>10}{:>8.1}",
            name,
            full as u64,
            c_sc as u64,
            100.0 * (1.0 - c_sc / full),
            c_sp as u64,
            100.0 * (1.0 - c_sp / full),
            c_spk as u64,
            100.0 * (1.0 - c_spk / full),
        );

        // wall-clock confirmation on one representative pair
        let x = &ds.test.series[0];
        let y = &ds.train.series[0];
        let spdtw = SpDtw::new(loc_w);
        let spk = SpKrdtw::new(loc_m, 1.0);
        Bench::header(&format!("{name} wall-clock (T={t}, θ={theta}, band={band_pct}%)"));
        let mut b = Bench::new(2, 8);
        b.run("DTW", || Dtw.dist(x, y).value);
        b.run("DTW_sc", || sc.dist(x, y).value);
        b.run("SP-DTW", || spdtw.dist(x, y).value);
        b.run("Krdtw", || Krdtw::new(1.0).log_k(x, y).value);
        b.run("SP-Krdtw", || spk.log_k(x, y).value);
        let r = b.results();
        println!(
            "-> wall-clock speed-up: SP-DTW {:.1}x vs DTW | SP-Krdtw {:.1}x vs Krdtw\n",
            r[0].mean_s / r[2].mean_s,
            r[3].mean_s / r[4].mean_s
        );
    }
}
