//! Tables II & IV regeneration (scaled): 1-NN + SVM error rates for all
//! measures over a slice of the archive, with Wilcoxon p-values (Tables
//! III & V).  The full sweep is `spdtw experiment all`; this bench is a
//! fast-feedback subset.
//!
//! `SPDTW_BENCH_DATASETS=a,b,c cargo bench --bench bench_accuracy`

use spdtw::config::ExperimentConfig;
use spdtw::experiments::runner::{evaluate_dataset, NN_METHODS, SVM_METHODS};
use spdtw::stats::mean_ranks;
use spdtw::stats::wilcoxon::wilcoxon_signed_rank;

fn main() {
    let datasets: Vec<String> = std::env::var("SPDTW_BENCH_DATASETS")
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|_| {
            ["CBF", "SyntheticControl", "Gun-Point", "ECGFiveDays", "Wine", "FacesUCR"]
                .iter()
                .map(|s| s.to_string())
                .collect()
        });
    let cfg = ExperimentConfig {
        max_train: 24,
        max_test: 30,
        ..Default::default()
    };

    let mut header = format!("{:<18}", "dataset");
    for m in NN_METHODS {
        header.push_str(&format!("{m:>10}"));
    }
    println!("== Table II (1-NN error, scaled) ==\n{header}");

    let mut evals = Vec::new();
    let mut nn_rows: Vec<Vec<f64>> = Vec::new();
    for name in &datasets {
        let t0 = std::time::Instant::now();
        let ev = match evaluate_dataset(&cfg, name, true) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skip {name}: {e}");
                continue;
            }
        };
        let mut row = format!("{:<18}", ev.name);
        let mut numeric = Vec::new();
        for m in NN_METHODS {
            row.push_str(&format!("{:>10.3}", ev.err_1nn[*m]));
            numeric.push(ev.err_1nn[*m]);
        }
        println!("{row}   ({:.1}s)", t0.elapsed().as_secs_f64());
        nn_rows.push(numeric);
        evals.push(ev);
    }
    let ranks = mean_ranks(&nn_rows);
    let mut row = format!("{:<18}", "Mean rank");
    for r in &ranks {
        row.push_str(&format!("{r:>10.2}"));
    }
    println!("{row}");

    println!("\n== Table III (Wilcoxon p-values, 1-NN) ==");
    let pick = |m: &str| -> Vec<f64> { evals.iter().map(|e| e.err_1nn[m]).collect() };
    for (a, b) in [
        ("DTW", "SP-DTW"),
        ("DTW_sc", "SP-DTW"),
        ("DTW_sc", "SP-Krdtw"),
        ("Krdtw", "SP-Krdtw"),
        ("Ed", "SP-Krdtw"),
    ] {
        let w = wilcoxon_signed_rank(&pick(a), &pick(b));
        println!("  {a:>8} vs {b:<9}: p = {:.4} (W = {}, n = {})", w.p_value, w.w, w.n_used);
    }

    println!("\n== Table IV (SVM error, scaled) ==");
    let mut header = format!("{:<18}", "dataset");
    for m in SVM_METHODS {
        header.push_str(&format!("{m:>10}"));
    }
    println!("{header}");
    let mut svm_rows = Vec::new();
    for ev in &evals {
        let mut row = format!("{:<18}", ev.name);
        let mut numeric = Vec::new();
        for m in SVM_METHODS {
            row.push_str(&format!("{:>10.3}", ev.err_svm[*m]));
            numeric.push(ev.err_svm[*m]);
        }
        println!("{row}");
        svm_rows.push(numeric);
    }
    let ranks = mean_ranks(&svm_rows);
    let mut row = format!("{:<18}", "Mean rank");
    for r in &ranks {
        row.push_str(&format!("{r:>10.2}"));
    }
    println!("{row}");

    println!("\n== Table V (Wilcoxon p-values, SVM) ==");
    let pick = |m: &str| -> Vec<f64> { evals.iter().map(|e| e.err_svm[m]).collect() };
    for (a, b) in [("Ed", "SP-Krdtw"), ("Krdtw", "SP-Krdtw"), ("Krdtw_sc", "SP-Krdtw")] {
        let w = wilcoxon_signed_rank(&pick(a), &pick(b));
        println!("  {a:>8} vs {b:<9}: p = {:.4}", w.p_value);
    }
}
