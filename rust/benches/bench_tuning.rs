//! Fig. 4 regeneration + tuning-cost bench: the LOO θ grid-search curves
//! for the paper's three example datasets, with the wall-clock cost of
//! each tuning stage.

use spdtw::config::ExperimentConfig;
use spdtw::experiments::runner::load_dataset;
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::tuning;
use spdtw::util::timer::Stopwatch;

fn main() {
    let cfg = ExperimentConfig {
        max_train: 24,
        max_test: 8,
        ..Default::default()
    };
    for name in ["50Words", "FacesUCR", "Wine"] {
        let ds = match load_dataset(&cfg, name) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skip {name}: {e}");
                continue;
            }
        };
        let mut sw = Stopwatch::new();
        let grid = sw.measure("learn grid", || learn_occupancy_grid(&ds.train, cfg.threads));
        let (best, curve) = sw.measure("θ grid search (LOO)", || {
            tuning::tune_theta(&grid, &ds.train, 1.0, &tuning::theta_grid(), cfg.threads)
        });
        let (band, _) = sw.measure("band grid search (LOO)", || {
            tuning::tune_band_pct(&ds.train, &tuning::band_pct_grid(), cfg.threads)
        });
        println!("\n== Fig. 4 curve — {name} (T={}) ==", ds.series_len());
        println!("{:>6} {:>10} {:>12}", "θ", "LOO err", "cells");
        for (theta, err) in &curve {
            let cells = grid.threshold(*theta).to_loc(1.0).nnz();
            let mark = if *theta == best { "  <- θ*" } else { "" };
            println!("{theta:>6} {err:>10.3} {cells:>12}{mark}");
        }
        println!("optimal θ={best}, optimal band={band}%");
        println!("{}", sw.report());
    }
}
