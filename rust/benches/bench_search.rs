//! Search-cascade benches (§Perf): pruning power and wall-clock of the
//! cascaded lower-bound + early-abandoning k-NN engine vs brute-force
//! scanning, on synthetic UCR-style workloads — for both banded DTW and
//! the SP-DTW sparse-grid composition.
//!
//! Reported per configuration: error rate, per-stage prune counts, the
//! pruning ratio (candidates resolved without a completed full DP), DP
//! cells vs the brute-force cell count, and throughput.

use std::sync::Arc;
use std::time::Instant;

use spdtw::classify::nn::classify_knn;
use spdtw::data::synthetic;
use spdtw::measures::dtw::BandedDtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::search::{persist, Cascade, Index, SearchEngine};
use spdtw::sparse::learn::learn_occupancy_grid;

fn run_engine(
    label: &str,
    index: &Arc<Index>,
    cascade: Cascade,
    ds: &spdtw::data::Dataset,
    k: usize,
    brute_cells: u64,
    brute_secs: f64,
) {
    let engine = SearchEngine::new(Arc::clone(index), cascade);
    let t0 = Instant::now();
    let (eval, stats) = engine.classify(&ds.test, k, 8);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "  {label:<22} error={:.3}  pruned {:>5.1}%  (kim {} / keogh {} / rev {} / abandon {})  \
         DP cells {:>10} ({:>5.1}% of brute)  {:>7.0} q/s ({:.2}x)",
        eval.error_rate,
        100.0 * stats.prune_ratio(),
        stats.kim_pruned,
        stats.keogh_pruned,
        stats.rev_pruned,
        stats.abandoned,
        stats.dp_cells,
        100.0 * stats.dp_cells as f64 / brute_cells.max(1) as f64,
        ds.test.len() as f64 / dt,
        brute_secs / dt.max(1e-9),
    );
}

fn main() {
    let k = 1;
    for name in ["CBF", "SyntheticControl", "Gun-Point"] {
        let ds = synthetic::generate_scaled(name, 42, 60, 60).unwrap();
        let t = ds.series_len();
        let band = ((t as f64) * 0.1).round().max(1.0) as usize;
        println!(
            "{name}: T={t} train={} test={} band={band}",
            ds.train.len(),
            ds.test.len()
        );

        // ---- brute-force baseline (exhaustive banded DTW) ----------------
        let t0 = Instant::now();
        let brute = classify_knn(&BandedDtw(band), &ds.train, &ds.test, k, 8);
        let brute_secs = t0.elapsed().as_secs_f64();
        println!(
            "  {:<22} error={:.3}  DP cells {:>10}  {:>7.0} q/s",
            "brute force",
            brute.error_rate,
            brute.visited_cells,
            ds.test.len() as f64 / brute_secs
        );

        // ---- cascade ablation over the banded-DTW index -------------------
        let index = Arc::new(Index::build(&ds.train, band, 8));
        run_engine(
            "full cascade",
            &index,
            Cascade::default(),
            &ds,
            k,
            brute.visited_cells,
            brute_secs,
        );
        run_engine(
            "no early abandon",
            &index,
            Cascade { early_abandon: false, ..Cascade::default() },
            &ds,
            k,
            brute.visited_cells,
            brute_secs,
        );
        run_engine(
            "lower bounds only",
            &index,
            Cascade {
                kim: true,
                keogh: true,
                keogh_rev: false,
                early_abandon: false,
                order_by_lb: true,
            },
            &ds,
            k,
            brute.visited_cells,
            brute_secs,
        );
        run_engine(
            "abandon only",
            &index,
            Cascade {
                kim: false,
                keogh: false,
                keogh_rev: false,
                early_abandon: true,
                order_by_lb: false,
            },
            &ds,
            k,
            brute.visited_cells,
            brute_secs,
        );

        // ---- lane sweep: scalar vs lane-batched survivor loop -------------
        bench_lane_sweep("lane sweep (dtw)", &index, &ds);

        // ---- SP-DTW composition: sparse grid × cascade --------------------
        let grid = learn_occupancy_grid(&ds.train, 8);
        let loc = Arc::new(grid.threshold(1.0).to_loc(1.0));
        let t0 = Instant::now();
        let sp = SpDtw::from_arc(Arc::clone(&loc));
        let sp_brute = classify_knn(&sp, &ds.train, &ds.test, k, 8);
        let sp_secs = t0.elapsed().as_secs_f64();
        println!(
            "  {:<22} error={:.3}  DP cells {:>10}  ({} nnz, {:.1}% sparse)",
            "sp-dtw brute",
            sp_brute.error_rate,
            sp_brute.visited_cells,
            loc.nnz(),
            100.0 * loc.sparsity()
        );
        let sp_index = Arc::new(Index::build_spdtw(&ds.train, loc, 8));
        run_engine(
            "sp-dtw + cascade",
            &sp_index,
            Cascade::default(),
            &ds,
            k,
            sp_brute.visited_cells,
            sp_secs,
        );
        bench_lane_sweep("lane sweep (sp-dtw)", &sp_index, &ds);

        // ---- persistence: cold build vs warm load -------------------------
        // The measured claim behind the index store: a serving restart
        // that reloads the .spix file instead of rebuilding.
        bench_persistence(name, &ds, band);

        // ---- concurrent submitters: aggregate engine QPS ------------------
        // One shared engine, N threads each running batch_knn: every
        // call is its own compute-pool epoch, so throughput should grow
        // with submitters instead of flat-lining behind a submit lock.
        bench_concurrent_submitters(&index, &ds);
        println!();
    }
}

/// Scalar-vs-lane sweep (L ∈ {1, 4, 8}) over the EA survivor loop,
/// single-threaded so the ratio is pure kernel throughput rather than
/// pool scheduling.  Results are asserted bit-identical at every width
/// (the lane contract), so every row reports the *same* neighbors.
fn bench_lane_sweep(label: &str, index: &Arc<Index>, ds: &spdtw::data::Dataset) {
    let base = SearchEngine::with_lanes(Arc::clone(index), Cascade::default(), 1);
    let t0 = Instant::now();
    let (eval1, stats1) = base.classify(&ds.test, 1, 1);
    let base_secs = t0.elapsed().as_secs_f64();
    println!(
        "  {label:<22} L=1  error={:.3}  DP cells {:>10}  {:>7.0} q/s",
        eval1.error_rate,
        stats1.dp_cells,
        ds.test.len() as f64 / base_secs.max(1e-9),
    );
    for lanes in [4usize, 8] {
        let eng = SearchEngine::with_lanes(Arc::clone(index), Cascade::default(), lanes);
        for probe in ds.test.series.iter().take(8) {
            let (ra, rb) = (base.knn(probe, 3), eng.knn(probe, 3));
            for (na, nb) in ra.neighbors.iter().zip(&rb.neighbors) {
                assert_eq!(na.dist.to_bits(), nb.dist.to_bits());
                assert_eq!(na.train_idx, nb.train_idx);
            }
        }
        let t0 = Instant::now();
        let (eval, stats) = eng.classify(&ds.test, 1, 1);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(eval.error_rate, eval1.error_rate);
        println!(
            "  {label:<22} L={lanes}  error={:.3}  DP cells {:>10}  {:>7.0} q/s ({:.2}x vs L=1)",
            eval.error_rate,
            stats.dp_cells,
            ds.test.len() as f64 / dt.max(1e-9),
            base_secs / dt.max(1e-9),
        );
    }
}

fn bench_concurrent_submitters(index: &Arc<Index>, ds: &spdtw::data::Dataset) {
    let total_batches = 16usize;
    for submitters in [1usize, 2, 4, 8] {
        let per = total_batches / submitters;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..submitters)
            .map(|_| {
                let engine = SearchEngine::new(Arc::clone(index), Cascade::default());
                let queries = ds.test.clone();
                std::thread::spawn(move || {
                    let mut served = 0usize;
                    for _ in 0..per {
                        served += engine.batch_knn(&queries, 1, 4).len();
                    }
                    served
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {:<22} {submitters} submitter(s): {total:>6} queries  {:>8.0} q/s aggregate",
            "concurrent epochs",
            total as f64 / dt.max(1e-9),
        );
    }
}

fn bench_persistence(name: &str, ds: &spdtw::data::Dataset, band: usize) {
    let path = std::env::temp_dir().join(format!(
        "spdtw_bench_{}_{name}.spix",
        std::process::id()
    ));

    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(Index::build(&ds.train, band, 8));
    }
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let index = Arc::new(Index::build(&ds.train, band, 8));
    persist::save_index(&index, &path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len();

    let t0 = Instant::now();
    let mut warm = None;
    for _ in 0..reps {
        warm = Some(std::hint::black_box(persist::load_index(&path).unwrap()));
    }
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    // warm-loaded index must answer identically (spot-check the bench
    // queries so the reported speed-up is for the *same* results)
    let warm = Arc::new(warm.unwrap());
    let a = SearchEngine::new(Arc::clone(&index), Cascade::default());
    let b = SearchEngine::new(warm, Cascade::default());
    for probe in ds.test.series.iter().take(8) {
        let (ra, rb) = (a.knn(probe, 1), b.knn(probe, 1));
        assert_eq!(ra.neighbors[0].dist.to_bits(), rb.neighbors[0].dist.to_bits());
        assert_eq!(ra.neighbors[0].train_idx, rb.neighbors[0].train_idx);
    }
    println!(
        "  {:<22} cold build {cold_ms:>8.2} ms | warm load {warm_ms:>8.2} ms ({:.1}x, {} KiB file)",
        "index persistence",
        cold_ms / warm_ms.max(1e-9),
        file_bytes / 1024,
    );
    std::fs::remove_file(&path).ok();
}
