//! Coordinator throughput/latency benches (§Perf): native vs PJRT
//! backends, batch-size sensitivity, flush-policy sweep, the
//! coordinator-overhead measurement (submit/dispatch/respond cost vs
//! direct evaluation), and the multi-client scenario — aggregate k-NN
//! QPS at 1/2/4/8 concurrent submitters over the concurrent-epoch
//! compute pool, written to `BENCH_COORDINATOR.json` (EXPERIMENTS.md
//! §PR 4).

use std::sync::Arc;
use std::time::Instant;

use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::data::TimeSeries;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::runtime::PjrtRuntime;
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::sparse::LocMatrix;

fn throughput(
    coord: &Coordinator,
    key: spdtw::coordinator::state::GridKey,
    queries: &[(TimeSeries, TimeSeries)],
) -> (f64, f64) {
    let t0 = Instant::now();
    let tickets: Vec<_> = queries
        .iter()
        .map(|(x, y)| coord.submit_spdtw(key, x, y).unwrap())
        .collect();
    coord.flush();
    for t in tickets {
        t.wait().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    (queries.len() as f64 / dt, dt)
}

fn main() {
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 60, 64).unwrap();
    let grid = learn_occupancy_grid(&ds.train, 8);
    let loc = grid.threshold(2.0).to_loc(1.0);
    let n = 1024;
    let queries: Vec<_> = (0..n)
        .map(|i| {
            (
                ds.test.series[i % ds.test.len()].clone(),
                ds.train.series[(i * 7) % ds.train.len()].clone(),
            )
        })
        .collect();

    // ---- direct-eval baseline (no coordinator) ---------------------------
    let sp = SpDtw::new(loc.clone());
    let t0 = Instant::now();
    for (x, y) in &queries {
        std::hint::black_box(sp.dist(x, y).value);
    }
    let direct = queries.len() as f64 / t0.elapsed().as_secs_f64();
    println!("direct eval (single thread):     {direct:>10.0} pairs/s");

    // ---- native backend, worker sweep -------------------------------------
    for workers in [1usize, 2, 4, 8] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let key = coord.register_grid(loc.clone()).unwrap();
        let (rate, _) = throughput(&coord, key, &queries);
        println!("native backend, {workers} workers:     {rate:>10.0} pairs/s");
    }

    // ---- multi-client scenario: aggregate QPS at 1/2/4/8 submitters -------
    // The measured claim behind the concurrent-epoch scheduler: N
    // clients issuing batch k-NN requests each run as their own pool
    // epoch and overlap; under the old global submit lock aggregate QPS
    // was flat in N.  Total query count is held constant across client
    // counts so the rows compare directly.  (Runs before the PJRT
    // section, which bails out of main when no artifacts exist.)
    bench_multi_client(&ds);

    // ---- wire throughput: v2 dist ops over real TCP ------------------------
    bench_wire_dist(&ds);

    // ---- pjrt backend, flush-policy sweep ----------------------------------
    let artifacts = std::path::PathBuf::from("artifacts");
    let Ok(rt) = PjrtRuntime::start(&artifacts) else {
        println!("(pjrt benches skipped: run `make artifacts`)");
        return;
    };
    for flush_us in [200u64, 1_000, 5_000] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                prefer_pjrt: true,
                flush_us,
                ..Default::default()
            },
            Some(rt.handle()),
        )
        .unwrap();
        let key = coord.register_grid(loc.clone()).unwrap();
        // warmup (first batch compiles the executable)
        let w = coord.submit_spdtw(key, &queries[0].0, &queries[0].1).unwrap();
        coord.flush();
        w.wait().unwrap();
        let (rate, _) = throughput(&coord, key, &queries);
        let snap = coord.metrics();
        println!(
            "pjrt backend, flush={flush_us:>5}µs:   {rate:>10.0} pairs/s  \
             ({} batches, {} padded, p99 ≤ {:.0}µs)",
            snap.batches,
            snap.padded_slots,
            snap.latency_percentile_us(99.0)
        );
    }

    // ---- coordinator overhead (tiny jobs stress the dispatch path) --------
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let tiny = LocMatrix::corridor(8, 1);
    let key = coord.register_grid(tiny).unwrap();
    let x = TimeSeries::new(0, vec![0.5; 8]);
    let y = TimeSeries::new(0, vec![-0.5; 8]);
    let t0 = Instant::now();
    let m = 20_000;
    let tickets: Vec<_> = (0..m)
        .map(|_| coord.submit_spdtw(key, &x, &y).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let per_job = t0.elapsed().as_secs_f64() / m as f64;
    println!(
        "coordinator overhead (T=8 jobs): {:>10.2} µs/job end-to-end",
        per_job * 1e6
    );

    // ---- search path: cascade pruning through the coordinator -------------
    use spdtw::search::{Cascade, Index};
    let coord = Coordinator::start(CoordinatorConfig::default(), None).unwrap();
    let band = (ds.series_len() as f64 * 0.1).round() as usize;
    let key = coord.register_index(Index::build(&ds.train, band, 8));
    let t0 = Instant::now();
    let tickets: Vec<_> = ds
        .test
        .series
        .iter()
        .map(|probe| coord.submit_search(key, probe, 1, Cascade::default()).unwrap())
        .collect();
    let nq = tickets.len();
    for t in tickets {
        t.wait().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    coord.wait_native_idle();
    let snap = coord.metrics();
    println!(
        "search requests: {} queries in {:.1} ms ({:.0} q/s), prune ratio {:.1}%",
        nq,
        dt * 1e3,
        nq as f64 / dt,
        100.0 * snap.search_prune_ratio()
    );
    println!(
        "  stage exits: {} kim / {} keogh / {} rev / {} abandons / {} full DPs over {} candidates",
        snap.lb_kim_skips,
        snap.lb_keogh_skips,
        snap.lb_rev_skips,
        snap.early_abandons,
        snap.full_dp_evals,
        snap.search_candidates
    );
    println!("{}", snap.report());
}

/// Wire-protocol cost of the generic pairwise op: N TCP clients each
/// drive sequential v2 `dist` envelopes (one JSON line per op, id echo
/// checked) against one server.  Run twice per client count — bare and
/// with a generous `deadline_ms` on every request — so the line also
/// measures what the three deadline checkpoints cost on the happy path
/// (they should be in the noise).
fn bench_wire_dist(ds: &spdtw::data::Dataset) {
    use spdtw::coordinator::server::{Client, Server};
    use spdtw::util::json::Json;

    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
    let server = Server::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let total_ops = 2_048usize;
    println!("\nwire v2 dist ops ({total_ops} ops total, per-op round trip over TCP):");
    for deadline_ms in [None, Some(60_000u64)] {
        for clients in [1usize, 2, 4] {
            let per_client = total_ops / clients;
            let t0 = Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let addr = server.addr.to_string();
                    let x = ds.test.series[c % ds.test.len()].values.clone();
                    let y = ds.train.series[(c * 3) % ds.train.len()].values.clone();
                    std::thread::spawn(move || {
                        let mut client = Client::connect(&addr).unwrap();
                        for i in 0..per_client {
                            let mut fields = vec![
                                ("proto", Json::num(2.0)),
                                ("id", Json::num(i as f64)),
                                ("op", Json::str("dist")),
                                ("measure", Json::obj(vec![("kind", Json::str("dtw"))])),
                                ("x", Json::arr(x.iter().copied().map(Json::num))),
                                ("y", Json::arr(y.iter().copied().map(Json::num))),
                            ];
                            if let Some(ms) = deadline_ms {
                                fields.push(("deadline_ms", Json::num(ms as f64)));
                            }
                            let reply = client.call(&Json::obj(fields)).unwrap();
                            assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
                            assert_eq!(reply.req_usize("id").unwrap(), i);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            let tag = if deadline_ms.is_some() {
                "deadline_ms=60000"
            } else {
                "no deadline     "
            };
            println!(
                "  {clients} client(s), {tag}: {:>7.0} ops/s ({:>6.1} µs/op)",
                (clients * per_client) as f64 / dt,
                dt * 1e6 / (clients * per_client) as f64
            );
        }
    }
}

fn bench_multi_client(ds: &spdtw::data::Dataset) {
    use spdtw::search::{Cascade, Index};
    use spdtw::util::json::Json;

    let band = (ds.series_len() as f64 * 0.1).round().max(1.0) as usize;
    let total_batches = 16usize;
    println!(
        "\nmulti-client batch search ({} queries per batch, {} batches total):",
        ds.test.len(),
        total_batches
    );
    let mut records: Vec<Json> = Vec::new();
    for clients in [1usize, 2, 4, 8] {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig::default(), None).unwrap());
        let key = coord.register_index(Index::build(&ds.train, band, 8));
        // warmup: grow every pool workspace to steady state
        coord
            .submit_batch_search(key, &ds.test.series, 1, Cascade::default())
            .unwrap()
            .wait()
            .unwrap();
        let per_client = total_batches / clients;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let coord = Arc::clone(&coord);
                let queries = ds.test.series.clone();
                std::thread::spawn(move || {
                    let mut served = 0usize;
                    for _ in 0..per_client {
                        let outs = coord
                            .submit_batch_search(key, &queries, 1, Cascade::default())
                            .unwrap()
                            .wait()
                            .unwrap();
                        served += outs.len();
                    }
                    served
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let dt = t0.elapsed().as_secs_f64();
        let qps = total as f64 / dt;
        let snap = coord.metrics();
        println!(
            "  {clients} client(s): {total:>6} queries in {:>8.1} ms -> {qps:>9.0} q/s  \
             (peak {} concurrent requests)",
            dt * 1e3,
            snap.peak_concurrent_requests,
        );
        records.push(Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            ("queries", Json::num(total as f64)),
            ("secs", Json::num(dt)),
            ("qps", Json::num(qps)),
            (
                "peak_concurrent_requests",
                Json::num(snap.peak_concurrent_requests as f64),
            ),
            (
                "pool_peak_epochs",
                Json::num(snap.pool.peak_concurrent_epochs as f64),
            ),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::str("multi_client_batch_search")),
        ("dataset", Json::str(ds.name.clone())),
        ("series_len", Json::num(ds.series_len() as f64)),
        ("train", Json::num(ds.train.len() as f64)),
        ("queries_per_batch", Json::num(ds.test.len() as f64)),
        ("records", Json::Arr(records)),
    ]);
    if std::fs::write("BENCH_COORDINATOR.json", out.to_pretty()).is_ok() {
        println!("wrote BENCH_COORDINATOR.json");
    }
}
