//! Streaming monitor bench (EXPERIMENTS.md §Streaming): per-window
//! latency and windows/s for (a) the exact sliding-cascade path, (b) a
//! from-scratch batch search per window — the work streaming replaces —
//! and (c) the approximate RWS pre-filter across candidate budgets with
//! measured recall@k, written to `BENCH_STREAM.json`.  Every exact-path
//! window is cross-checked bitwise against the batch engine before any
//! timing, so a row can never report the speed of a wrong answer; RWS
//! rows time an unaudited pass and measure recall on a separate
//! audit-every-window pass, so the dial's speed and its accuracy come
//! from runs that each do only their own work.

use std::sync::Arc;
use std::time::Instant;

use spdtw::data::synthetic;
use spdtw::search::{Cascade, Index, SearchEngine};
use spdtw::stream::{RwsConfig, StreamMonitor};
use spdtw::util::json::Json;
use spdtw::util::mathx::percentile;

const K: usize = 5;

fn engine(index: &Arc<Index>) -> SearchEngine {
    SearchEngine::new(Arc::clone(index), Cascade::default())
}

/// Drive one monitor over the whole stream, timing each sample that
/// completes a window; returns (windows, total secs, per-window µs).
fn run_stream(mut monitor: StreamMonitor, stream: &[f64]) -> (u64, f64, Vec<f64>) {
    let mut lat_us = Vec::new();
    let mut windows = 0u64;
    let t0 = Instant::now();
    for &v in stream {
        let tq = Instant::now();
        if std::hint::black_box(monitor.push(v).unwrap()).is_some() {
            lat_us.push(tq.elapsed().as_secs_f64() * 1e6);
            windows += 1;
        }
    }
    (windows, t0.elapsed().as_secs_f64(), lat_us)
}

fn main() {
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 64, 16).unwrap();
    let t = ds.series_len();
    let band = (t as f64 * 0.1).round().max(1.0) as usize;
    let index = Arc::new(Index::build(&ds.train, band, 2));
    // the concatenated test split is the drifting stream: every T
    // samples the source series (and its class) changes under the
    // monitor's feet
    let stream: Vec<f64> = ds
        .test
        .series
        .iter()
        .flat_map(|s| s.values.iter().copied())
        .collect();
    let total_windows = stream.len() + 1 - t;
    println!(
        "stream bench: {} train series of length {t}, k={K}, {} samples -> {total_windows} windows",
        ds.train.len(),
        stream.len()
    );

    // exactness gate: every streamed window must answer bit-identically
    // to a from-scratch batch search over the same window
    let eng = engine(&index);
    let mut monitor = StreamMonitor::new(engine(&index), K, None).unwrap();
    let mut checked = 0usize;
    for (i, &v) in stream.iter().enumerate() {
        if let Some(rep) = monitor.push(v).unwrap() {
            let start = i + 1 - t;
            let want = eng.knn_values(&stream[start..=i], K);
            assert_eq!(rep.neighbors.len(), want.neighbors.len());
            for (g, w) in rep.neighbors.iter().zip(&want.neighbors) {
                assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "window {start}");
                assert_eq!(g.train_idx, w.train_idx, "window {start}");
            }
            checked += 1;
        }
    }
    assert_eq!(checked, total_windows);
    println!("  exactness: {checked}/{total_windows} windows bit-identical to batch");

    let mut records: Vec<Json> = Vec::new();
    let mut row = |label: &str, windows: u64, secs: f64, lat_us: &[f64], extra: Vec<(&str, Json)>| {
        let wps = windows as f64 / secs;
        let p50 = percentile(lat_us, 50.0);
        let p99 = percentile(lat_us, 99.0);
        println!("  {label:<24} {wps:>8.0} windows/s  p50 {p50:>7.1} us  p99 {p99:>7.1} us");
        let mut fields = vec![
            ("path", Json::str(label)),
            ("windows", Json::num(windows as f64)),
            ("secs", Json::num(secs)),
            ("windows_per_s", Json::num(wps)),
            ("p50_us", Json::num(p50)),
            ("p99_us", Json::num(p99)),
        ];
        fields.extend(extra);
        records.push(Json::obj(fields));
    };

    // row: exact streaming (sliding envelopes, incremental window)
    let (w, secs, lat) = run_stream(StreamMonitor::new(engine(&index), K, None).unwrap(), &stream);
    row("stream_exact", w, secs, &lat, vec![("recall_at_k", Json::num(1.0))]);

    // row: batch per window — rebuild the query envelope from scratch
    // every step, the cost the sliding monitor amortizes away
    {
        let mut lat_us = Vec::with_capacity(total_windows);
        let t0 = Instant::now();
        for s in 0..total_windows {
            let tq = Instant::now();
            std::hint::black_box(eng.knn_values(&stream[s..s + t], K));
            lat_us.push(tq.elapsed().as_secs_f64() * 1e6);
        }
        let secs = t0.elapsed().as_secs_f64();
        row(
            "batch_per_window",
            total_windows as u64,
            secs,
            &lat_us,
            vec![("recall_at_k", Json::num(1.0))],
        );
    }

    // rows: the RWS recall-vs-speed dial.  The timed pass never audits;
    // recall@k is measured on a second pass auditing every window.
    for candidates in [4usize, 8, 16, 32] {
        let timed_cfg = RwsConfig {
            candidates,
            audit_every: 0,
            ..RwsConfig::default()
        };
        let (w, secs, lat) =
            run_stream(StreamMonitor::new(engine(&index), K, Some(timed_cfg)).unwrap(), &stream);
        let audit_cfg = RwsConfig {
            candidates,
            audit_every: 1,
            ..RwsConfig::default()
        };
        let mut audited = StreamMonitor::new(engine(&index), K, Some(audit_cfg)).unwrap();
        for &v in &stream {
            audited.push(v).unwrap();
        }
        let recall = audited.stats().recall().expect("every window audited");
        println!("    rws candidates={candidates}: measured recall@{K} = {recall:.3}");
        row(
            &format!("stream_rws_c{candidates}"),
            w,
            secs,
            &lat,
            vec![
                ("candidates", Json::num(candidates as f64)),
                ("recall_at_k", Json::num(recall)),
            ],
        );
    }

    let out = Json::obj(vec![
        ("bench", Json::str("stream_monitor")),
        ("dataset", Json::str(ds.name.clone())),
        ("train", Json::num(ds.train.len() as f64)),
        ("series_len", Json::num(t as f64)),
        ("band", Json::num(band as f64)),
        ("k", Json::num(K as f64)),
        ("samples", Json::num(stream.len() as f64)),
        ("records", Json::Arr(records)),
    ]);
    if std::fs::write("BENCH_STREAM.json", out.to_pretty()).is_ok() {
        println!("wrote BENCH_STREAM.json");
    }
}
