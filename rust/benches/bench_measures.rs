//! Per-measure microbenchmarks: single-pair evaluation cost as a
//! function of T, plus cells/second throughput for the DP measures.
//! (in-tree harness; criterion is unavailable offline — DESIGN.md §2).
//!
//! Every DP kernel is measured twice — the allocating legacy path vs
//! the `DpWorkspace`-threaded `*_into`/`*_with` path — and the run
//! emits a machine-readable `BENCH_MEASURES.json` (per-kernel ns/call
//! and calls/sec for both paths) so the repo's perf trajectory is
//! tracked across PRs (EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;

use spdtw::data::TimeSeries;
use spdtw::measures::corr::CorrDist;
use spdtw::measures::daco::Daco;
use spdtw::measures::dtw::{dtw_banded_into, Dtw};
use spdtw::measures::euclidean::Euclidean;
use spdtw::measures::kga::Kga;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::sakoe_chiba::SakoeChibaDtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::workspace::DpWorkspace;
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::search::early::{dtw_banded_ea_into, spdtw_ea_into, EaResult};
use spdtw::search::lanes::{dtw_banded_ea_lanes_into, spdtw_ea_lanes_into};
use spdtw::sparse::LocMatrix;
use spdtw::util::bench::{Bench, BenchResult};
use spdtw::util::json::Json;
use spdtw::util::rng::Pcg64;

fn series(rng: &mut Pcg64, t: usize) -> TimeSeries {
    TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect())
}

/// One emitted record: kernel × path at one series length.
fn record(t: usize, kernel: &str, path: &str, r: &BenchResult) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("t".into(), Json::num(t as f64));
    obj.insert("kernel".into(), Json::str(kernel));
    obj.insert("path".into(), Json::str(path));
    obj.insert("ns_per_call".into(), Json::num(r.mean_s * 1e9));
    obj.insert("calls_per_sec".into(), Json::num(r.per_sec()));
    Json::Obj(obj)
}

fn main() {
    let mut rng = Pcg64::new(42);
    let mut records: Vec<Json> = Vec::new();
    for t in [64usize, 128, 256, 512] {
        let x = series(&mut rng, t);
        let y = series(&mut rng, t);
        let band = (0.1 * t as f64) as usize;
        let loc10 = LocMatrix::corridor(t, band); // ~matched cell budget
        let spdtw = SpDtw::new(loc10.clone());
        let spk = SpKrdtw::new(loc10, 1.0);

        Bench::header(&format!("single pair, T={t}"));
        let mut b = Bench::default();
        b.run("Ed", || Euclidean.dist(&x, &y).value);
        b.run("CORR", || CorrDist.dist(&x, &y).value);
        b.run("DACO(10)", || Daco::new(10).dist(&x, &y).value);
        b.run("DTW (full)", || Dtw.dist(&x, &y).value);
        b.run("DTW_sc (10%)", || SakoeChibaDtw::new(10.0).dist(&x, &y).value);
        b.run("SP-DTW (10% budget)", || spdtw.dist(&x, &y).value);
        b.run("Krdtw (full)", || Krdtw::new(1.0).log_k(&x, &y).value);
        b.run("Krdtw_sc", || {
            Krdtw::with_band(1.0, band).log_k(&x, &y).value
        });
        b.run("SP-Krdtw", || spk.log_k(&x, &y).value);
        b.run("Kga (full)", || Kga::new(1.0).log_k(&x, &y).value);

        // cells/second for the DP engines (roofline-style view)
        let full_cells = (t * t) as f64;
        let dtw_rate = full_cells * b.results()[3].per_sec();
        let sp_cells = spdtw.dist(&x, &y).visited_cells as f64;
        let sp_rate = sp_cells * b.results()[5].per_sec();
        println!(
            "-> DTW {:.1} Mcells/s | SP-DTW {:.1} Mcells/s (sparse iteration overhead here)",
            dtw_rate / 1e6,
            sp_rate / 1e6
        );

        // Allocating path vs workspace path for every DP kernel: the
        // "alloc" rows construct a fresh DpWorkspace per call (the cost
        // profile of the pre-workspace per-call Vec allocations); the
        // "workspace" rows reuse one warm arena — the steady-state
        // serving profile of gram/1-NN/search (EXPERIMENTS.md §Perf).
        Bench::header(&format!("alloc vs workspace, T={t}"));
        let xs = &x.values;
        let ys = &y.values;
        let mut ws = DpWorkspace::new();
        let mut p = Bench::default();

        let r = p.run("dtw_banded [alloc]", || {
            dtw_banded_into(&mut DpWorkspace::new(), xs, ys, usize::MAX).value
        });
        records.push(record(t, "dtw_banded", "alloc", r));
        let r = p.run("dtw_banded [workspace]", || {
            dtw_banded_into(&mut ws, xs, ys, usize::MAX).value
        });
        records.push(record(t, "dtw_banded", "workspace", r));

        let r = p.run("spdtw eval [alloc]", || {
            spdtw.eval_with(&mut DpWorkspace::new(), xs, ys).value
        });
        records.push(record(t, "spdtw", "alloc", r));
        let r = p.run("spdtw eval [workspace]", || spdtw.eval_with(&mut ws, xs, ys).value);
        records.push(record(t, "spdtw", "workspace", r));

        let kr = Krdtw::new(1.0);
        let r = p.run("krdtw [alloc]", || {
            kr.log_kernel_with(&mut DpWorkspace::new(), xs, ys).value
        });
        records.push(record(t, "krdtw", "alloc", r));
        let r = p.run("krdtw [workspace]", || kr.log_kernel_with(&mut ws, xs, ys).value);
        records.push(record(t, "krdtw", "workspace", r));

        let r = p.run("spkrdtw [alloc]", || {
            spk.log_kernel_with(&mut DpWorkspace::new(), xs, ys).value
        });
        records.push(record(t, "spkrdtw", "alloc", r));
        let r = p.run("spkrdtw [workspace]", || {
            spk.log_kernel_with(&mut ws, xs, ys).value
        });
        records.push(record(t, "spkrdtw", "workspace", r));

        let results = p.results();
        println!(
            "-> workspace speedups: dtw {:.2}x | spdtw {:.2}x | krdtw {:.2}x | spkrdtw {:.2}x",
            results[0].mean_s / results[1].mean_s,
            results[2].mean_s / results[3].mean_s,
            results[4].mean_s / results[5].mean_s,
            results[6].mean_s / results[7].mean_s,
        );

        // §Perf before/after: optimized hot loops vs the reference
        // implementations they replaced (EXPERIMENTS.md §Perf log).
        Bench::header(&format!("§Perf before/after, T={t}"));
        let mut q = Bench::default();
        q.run("dtw_banded_ref (before)", || {
            spdtw::measures::dtw::dtw_banded_ref(xs, ys, usize::MAX).value
        });
        q.run("dtw_banded (after)", || {
            spdtw::measures::dtw::dtw_banded(xs, ys, usize::MAX).value
        });
        q.run("spdtw eval_scan (before)", || spdtw.eval_scan(xs, ys).value);
        q.run("spdtw eval (after)", || spdtw.eval(xs, ys).value);
        q.run("spkrdtw scan (before)", || spk.log_kernel_scan(xs, ys).value);
        q.run("spkrdtw (after)", || spk.log_kernel(xs, ys).value);
        let r = q.results();
        println!(
            "-> speedups: dtw {:.2}x | spdtw {:.2}x | spkrdtw {:.2}x",
            r[0].mean_s / r[1].mean_s,
            r[2].mean_s / r[3].mean_s,
            r[4].mean_s / r[5].mean_s
        );

        // Lane-batched EA kernels (`search::lanes`): the same 8
        // survivors per timed call — scalar = the early.rs loop, laneN =
        // candidate-major groups of N.  ub = +inf so no lane abandons
        // (pure DP throughput; L=1 isolates the lane path's dispatch
        // overhead).  The sweep lands in BENCH_MEASURES.json as kernel
        // "dtw_ea"/"spdtw_ea" with path "scalar"/"lane1|lane4|lane8".
        Bench::header(&format!("lane-batched EA kernels, T={t}"));
        let cands: Vec<Vec<f64>> = (0..8).map(|_| series(&mut rng, t).values).collect();
        let lane_ys: Vec<&[f64]> = cands.iter().map(|c| c.as_slice()).collect();
        let inf = [f64::INFINITY; 8];
        let mut out = [EaResult {
            value: None,
            visited: 0,
        }; 8];
        let mut l = Bench::default();
        let r = l.run("dtw_ea [scalar x8]", || {
            let mut acc = 0.0;
            for c in &lane_ys {
                acc += dtw_banded_ea_into(&mut ws, xs, c, usize::MAX, f64::INFINITY)
                    .value
                    .unwrap();
            }
            acc
        });
        records.push(record(t, "dtw_ea", "scalar", r));
        for lanes in [1usize, 4, 8] {
            let r = l.run(&format!("dtw_ea [lane{lanes} x8]"), || {
                let mut acc = 0.0;
                for g in lane_ys.chunks(lanes) {
                    let gl = g.len();
                    dtw_banded_ea_lanes_into(
                        &mut ws,
                        xs,
                        g,
                        usize::MAX,
                        &inf[..gl],
                        &mut out[..gl],
                    );
                    for e in &out[..gl] {
                        acc += e.value.unwrap();
                    }
                }
                acc
            });
            records.push(record(t, "dtw_ea", &format!("lane{lanes}"), r));
        }
        let r = l.run("spdtw_ea [scalar x8]", || {
            let mut acc = 0.0;
            for c in &lane_ys {
                acc += spdtw_ea_into(&mut ws, &spdtw.loc, xs, c, f64::INFINITY)
                    .value
                    .unwrap();
            }
            acc
        });
        records.push(record(t, "spdtw_ea", "scalar", r));
        for lanes in [1usize, 4, 8] {
            let r = l.run(&format!("spdtw_ea [lane{lanes} x8]"), || {
                let mut acc = 0.0;
                for g in lane_ys.chunks(lanes) {
                    let gl = g.len();
                    spdtw_ea_lanes_into(&mut ws, &spdtw.loc, xs, g, &inf[..gl], &mut out[..gl]);
                    for e in &out[..gl] {
                        acc += e.value.unwrap();
                    }
                }
                acc
            });
            records.push(record(t, "spdtw_ea", &format!("lane{lanes}"), r));
        }
        let lr = l.results();
        println!(
            "-> lane speedups vs scalar: dtw_ea L4 {:.2}x L8 {:.2}x | spdtw_ea L4 {:.2}x L8 {:.2}x",
            lr[0].mean_s / lr[2].mean_s,
            lr[0].mean_s / lr[3].mean_s,
            lr[4].mean_s / lr[6].mean_s,
            lr[4].mean_s / lr[7].mean_s,
        );
    }

    let mut root = BTreeMap::new();
    root.insert("generated_by".into(), Json::str("bench_measures"));
    root.insert(
        "unit_note".into(),
        Json::str("ns_per_call mean over samples; alloc = fresh DpWorkspace per call"),
    );
    root.insert("records".into(), Json::Arr(records));
    let out = Json::Obj(root).to_pretty();
    let path = "BENCH_MEASURES.json";
    match std::fs::write(path, out) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
