//! Per-measure microbenchmarks: single-pair evaluation cost as a
//! function of T, plus cells/second throughput for the DP measures.
//! (in-tree harness; criterion is unavailable offline — DESIGN.md §2).

use spdtw::data::TimeSeries;
use spdtw::measures::corr::CorrDist;
use spdtw::measures::daco::Daco;
use spdtw::measures::dtw::Dtw;
use spdtw::measures::euclidean::Euclidean;
use spdtw::measures::kga::Kga;
use spdtw::measures::krdtw::Krdtw;
use spdtw::measures::sakoe_chiba::SakoeChibaDtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::spkrdtw::SpKrdtw;
use spdtw::measures::{KernelMeasure, Measure};
use spdtw::sparse::LocMatrix;
use spdtw::util::bench::Bench;
use spdtw::util::rng::Pcg64;

fn series(rng: &mut Pcg64, t: usize) -> TimeSeries {
    TimeSeries::new(0, (0..t).map(|_| rng.normal()).collect())
}

fn main() {
    let mut rng = Pcg64::new(42);
    for t in [64usize, 128, 256, 512] {
        let x = series(&mut rng, t);
        let y = series(&mut rng, t);
        let band = (0.1 * t as f64) as usize;
        let loc10 = LocMatrix::corridor(t, band); // ~matched cell budget
        let spdtw = SpDtw::new(loc10.clone());
        let spk = SpKrdtw::new(loc10, 1.0);

        Bench::header(&format!("single pair, T={t}"));
        let mut b = Bench::default();
        b.run("Ed", || Euclidean.dist(&x, &y).value);
        b.run("CORR", || CorrDist.dist(&x, &y).value);
        b.run("DACO(10)", || Daco::new(10).dist(&x, &y).value);
        b.run("DTW (full)", || Dtw.dist(&x, &y).value);
        b.run("DTW_sc (10%)", || SakoeChibaDtw::new(10.0).dist(&x, &y).value);
        b.run("SP-DTW (10% budget)", || spdtw.dist(&x, &y).value);
        b.run("Krdtw (full)", || Krdtw::new(1.0).log_k(&x, &y).value);
        b.run("Krdtw_sc", || {
            Krdtw::with_band(1.0, band).log_k(&x, &y).value
        });
        b.run("SP-Krdtw", || spk.log_k(&x, &y).value);
        b.run("Kga (full)", || Kga::new(1.0).log_k(&x, &y).value);

        // cells/second for the DP engines (roofline-style view)
        let full_cells = (t * t) as f64;
        let dtw_rate = full_cells * b.results()[3].per_sec();
        let sp_cells = SpDtw::new(LocMatrix::corridor(t, band))
            .dist(&x, &y)
            .visited_cells as f64;
        let sp_rate = sp_cells * b.results()[5].per_sec();
        println!(
            "-> DTW {:.1} Mcells/s | SP-DTW {:.1} Mcells/s (sparse iteration overhead visible here)",
            dtw_rate / 1e6,
            sp_rate / 1e6
        );

        // §Perf before/after: optimized hot loops vs the reference
        // implementations they replaced (EXPERIMENTS.md §Perf log).
        Bench::header(&format!("§Perf before/after, T={t}"));
        let mut p = Bench::default();
        let xs = &x.values;
        let ys = &y.values;
        p.run("dtw_banded_ref (before)", || {
            spdtw::measures::dtw::dtw_banded_ref(xs, ys, usize::MAX).value
        });
        p.run("dtw_banded (after)", || {
            spdtw::measures::dtw::dtw_banded(xs, ys, usize::MAX).value
        });
        p.run("spdtw eval_scan (before)", || spdtw_scan(&spdtw, xs, ys));
        p.run("spdtw eval (after)", || spdtw.eval(xs, ys).value);
        p.run("spkrdtw scan (before)", || spk.log_kernel_scan(xs, ys).value);
        p.run("spkrdtw (after)", || spk.log_kernel(xs, ys).value);
        let r = p.results();
        println!(
            "-> speedups: dtw {:.2}x | spdtw {:.2}x | spkrdtw {:.2}x",
            r[0].mean_s / r[1].mean_s,
            r[2].mean_s / r[3].mean_s,
            r[4].mean_s / r[5].mean_s
        );
    }
}

fn spdtw_scan(sp: &SpDtw, x: &[f64], y: &[f64]) -> f64 {
    sp.eval_scan(x, y).value
}
