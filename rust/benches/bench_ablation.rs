//! Ablation benches for the design choices DESIGN.md §4 calls out:
//!  A1. γ sweep        — weighting exponent vs accuracy (γ=0 sanity: plain
//!                       DTW costs on the retained cells).
//!  A2. θ sweep        — sparsity vs accuracy trade-off (the Fig. 4 curve
//!                       plus the cell counts the paper never shows).
//!  A3. weighted vs unweighted SP-DTW at the tuned θ.
//!  A4. symmetrization — learned grid vs its transpose-stripped half.

use spdtw::classify::nn::classify_1nn;
use spdtw::config::ExperimentConfig;
use spdtw::data::synthetic;
use spdtw::experiments::runner::load_dataset;
use spdtw::measures::dtw::Dtw;
use spdtw::measures::spdtw::SpDtw;
use spdtw::measures::Measure;
use spdtw::sparse::learn::learn_occupancy_grid;
use spdtw::sparse::LocMatrix;

fn main() {
    let cfg = ExperimentConfig {
        max_train: 24,
        max_test: 40,
        ..Default::default()
    };
    let name = std::env::var("SPDTW_BENCH_DATASET").unwrap_or_else(|_| "CBF".into());
    let ds = load_dataset(&cfg, &name).unwrap();
    let t = ds.series_len();
    let grid = learn_occupancy_grid(&ds.train, cfg.threads);
    let full_err = classify_1nn(&Dtw, &ds.train, &ds.test, cfg.threads).error_rate;
    println!("== ablations on {name} (T={t}) — DTW reference error {full_err:.3} ==");

    println!("\nA1: γ sweep (θ=2)");
    println!("{:>8}{:>10}{:>12}", "γ", "error", "cells");
    for gamma in [0.0, 0.25, 0.5, 1.0, 2.0, 4.0] {
        let loc = grid.threshold(2.0).to_loc(gamma);
        let cells = loc.nnz();
        let sp = SpDtw::new(loc);
        let err = classify_1nn(&sp, &ds.train, &ds.test, cfg.threads).error_rate;
        println!("{gamma:>8}{err:>10.3}{cells:>12}");
    }

    println!("\nA2: θ sweep (γ=1) — sparsity vs accuracy");
    println!("{:>8}{:>10}{:>12}{:>10}", "θ", "error", "cells", "S(%)");
    for theta in [0.0, 0.5, 1.0, 2.0, 5.0, 10.0, 15.0, 25.0] {
        let loc = grid.threshold(theta).to_loc(1.0);
        let cells = loc.nnz();
        let s = 100.0 * (1.0 - cells as f64 / (t * t) as f64);
        let sp = SpDtw::new(loc);
        let err = classify_1nn(&sp, &ds.train, &ds.test, cfg.threads).error_rate;
        println!("{theta:>8}{err:>10.3}{cells:>12}{s:>10.1}");
    }

    println!("\nA3: weighted vs unweighted at θ=2");
    for (label, gamma) in [("unweighted (mask only)", 0.0), ("weighted f(p)=p^-1", 1.0)] {
        let sp = SpDtw::new(grid.threshold(2.0).to_loc(gamma));
        let err = classify_1nn(&sp, &ds.train, &ds.test, cfg.threads).error_rate;
        println!("  {label:<26} error={err:.3}");
    }

    println!("\nA4: symmetrized grid vs upper-triangle-only");
    let loc = grid.threshold(2.0).to_loc(1.0);
    let upper = LocMatrix::from_triples(
        t,
        loc.to_triples().into_iter().filter(|&(r, c, _)| c >= r).collect(),
    );
    for (label, l) in [("symmetrized", loc), ("upper-only", upper)] {
        let cells = l.nnz();
        let sp = SpDtw::new(l);
        let err = classify_1nn(&sp, &ds.train, &ds.test, cfg.threads).error_rate;
        println!("  {label:<14} error={err:.3} cells={cells}");
    }

    println!("\nA6: the three speed-up families of §II-B.2 on one workload");
    println!("    (constraint = Sakoe-Chiba/Itakura, indexing = LB_Keogh cascade,");
    println!("     learned sparsification = SP-DTW — the paper's contribution)");
    {
        use spdtw::measures::itakura::{itakura_cells, ItakuraDtw};
        use spdtw::measures::lb_keogh::classify_1nn_lb;
        use spdtw::measures::sakoe_chiba::{band_cells, SakoeChibaDtw};
        let band = ((0.1 * t as f64) as usize).max(1);
        let full = (t * t) as f64;
        let sc = SakoeChibaDtw::new(10.0);
        let e_sc = classify_1nn(&sc, &ds.train, &ds.test, cfg.threads);
        let e_it = classify_1nn(&ItakuraDtw, &ds.train, &ds.test, cfg.threads);
        let (e_lb, skipped, total) = classify_1nn_lb(&ds.train, &ds.test, band);
        let loc = grid.threshold(2.0).to_loc(1.0);
        let spc = loc.nnz() as f64;
        let sp = SpDtw::new(loc);
        let e_sp = classify_1nn(&sp, &ds.train, &ds.test, cfg.threads);
        println!(
            "  {:<26} error={:.3}  cells/cmp={:>8}  S={:>5.1}%",
            "Sakoe-Chiba (10%)",
            e_sc.error_rate,
            band_cells(t, sc.band_for(t)),
            100.0 * (1.0 - band_cells(t, sc.band_for(t)) as f64 / full)
        );
        println!(
            "  {:<26} error={:.3}  cells/cmp={:>8}  S={:>5.1}%",
            "Itakura parallelogram",
            e_it.error_rate,
            itakura_cells(t),
            100.0 * (1.0 - itakura_cells(t) as f64 / full)
        );
        println!(
            "  {:<26} error={:.3}  DTW evals pruned: {}/{} ({:.1}%)",
            "LB_Keogh cascade (10%)",
            e_lb,
            skipped,
            total,
            100.0 * skipped as f64 / total as f64
        );
        println!(
            "  {:<26} error={:.3}  cells/cmp={:>8}  S={:>5.1}%",
            "SP-DTW (θ=2, learned)",
            e_sp.error_rate,
            spc as u64,
            100.0 * (1.0 - spc / full)
        );
    }

    // A5: learning-phase cost amortization
    let n = ds.train.len();
    let learn_cells = spdtw::sparse::learn::learning_cost_cells(n, t);
    let per_query_saved = (t * t) as u64 - grid.threshold(2.0).to_loc(1.0).nnz() as u64;
    println!(
        "\nA5: one-off learning cost = {learn_cells} cells; \
         per-query saving = {per_query_saved} cells -> break-even after {} queries",
        learn_cells / per_query_saved.max(1)
    );
    let _ = synthetic::generate_scaled("CBF", 1, 4, 2).unwrap(); // keep linkage honest
}
