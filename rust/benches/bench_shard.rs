//! Shard fan-out bench (EXPERIMENTS.md §Sharding): aggregate k-NN QPS
//! and per-query latency percentiles through the `ShardCoordinator`
//! against in-process fleets of 1 / 2 / 4 shard servers on loopback —
//! one fixed synthetic corpus, so rows compare directly — written to
//! `BENCH_SHARD.json`.  The merged answers at every shard count are
//! cross-checked bitwise against the 1-shard fleet before timing, so a
//! row can never report the throughput of a wrong answer.

use std::sync::Arc;
use std::time::Instant;

use spdtw::config::{CoordinatorConfig, ShardRole};
use spdtw::coordinator::server::Server;
use spdtw::coordinator::Coordinator;
use spdtw::data::synthetic;
use spdtw::shard::{ShardClientConfig, ShardCoordinator, ShardNeighbor, ShardRegistration};
use spdtw::util::json::Json;
use spdtw::util::mathx::percentile;

const K: usize = 5;
const TIMED_QUERIES: usize = 256;

fn start_fleet(shards_total: usize) -> (Vec<Server>, Arc<ShardCoordinator>) {
    let servers: Vec<Server> = (0..shards_total)
        .map(|i| {
            let cfg = CoordinatorConfig {
                shard: Some(ShardRole {
                    shard_id: i,
                    shards_total,
                }),
                workers: 2,
                ..Default::default()
            };
            let coord = Arc::new(Coordinator::start(cfg, None).unwrap());
            Server::start(coord, "127.0.0.1:0").unwrap()
        })
        .collect();
    let sc = ShardCoordinator::connect(ShardClientConfig::for_addrs(
        servers.iter().map(|s| s.addr.to_string()).collect(),
    ))
    .unwrap();
    (servers, sc)
}

fn main() {
    let ds = synthetic::generate_scaled("SyntheticControl", 42, 60, 64).unwrap();
    let band = (ds.series_len() as f64 * 0.1).round().max(1.0) as usize;
    let series: Vec<Vec<f64>> = ds.train.series.iter().map(|s| s.values.clone()).collect();
    let labels: Vec<usize> = ds.train.series.iter().map(|s| s.label).collect();
    let queries: Vec<&Vec<f64>> = (0..TIMED_QUERIES)
        .map(|i| &ds.test.series[i % ds.test.len()].values)
        .collect();
    println!(
        "shard fan-out bench: {} train series of length {}, k={K}, {} queries per row",
        series.len(),
        ds.series_len(),
        queries.len()
    );

    let mut reference: Vec<Vec<ShardNeighbor>> = Vec::new();
    let mut records: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4] {
        let (servers, sc) = start_fleet(shards);
        let si = sc
            .register(&ShardRegistration {
                name: Some("bench".to_string()),
                series: series.clone(),
                labels: labels.clone(),
                band: Some(band),
                measure: None,
            })
            .unwrap();

        // exactness cross-check + warmup: every fleet size must answer
        // bit-identically to the 1-shard fleet
        for (qi, q) in queries.iter().take(16).enumerate() {
            let got = sc.search(si.key, q, K, None).unwrap().neighbors;
            if shards == 1 {
                reference.push(got);
            } else {
                let want = &reference[qi];
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(want) {
                    assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "shards={shards} q={qi}");
                    assert_eq!(g.global_idx, w.global_idx, "shards={shards} q={qi}");
                }
            }
        }

        let mut lat_ms: Vec<f64> = Vec::with_capacity(queries.len());
        let t0 = Instant::now();
        for q in &queries {
            let tq = Instant::now();
            std::hint::black_box(sc.search(si.key, q, K, None).unwrap());
            lat_ms.push(tq.elapsed().as_secs_f64() * 1e3);
        }
        let secs = t0.elapsed().as_secs_f64();
        let qps = queries.len() as f64 / secs;
        let p50 = percentile(&lat_ms, 50.0);
        let p99 = percentile(&lat_ms, 99.0);
        let snap = sc.metrics();
        let candidates_per_query = snap.merge_candidates as f64 / snap.merges as f64;
        println!(
            "  {shards} shard(s): {qps:>8.0} q/s  p50 {p50:>7.3} ms  p99 {p99:>7.3} ms  \
             ({candidates_per_query:.1} merge candidates/query)",
        );
        records.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("queries", Json::num(queries.len() as f64)),
            ("secs", Json::num(secs)),
            ("qps", Json::num(qps)),
            ("p50_ms", Json::num(p50)),
            ("p99_ms", Json::num(p99)),
            ("merge_candidates_per_query", Json::num(candidates_per_query)),
        ]));
        drop(servers);
    }

    let out = Json::obj(vec![
        ("bench", Json::str("shard_fanout_search")),
        ("dataset", Json::str(ds.name.clone())),
        ("train", Json::num(series.len() as f64)),
        ("series_len", Json::num(ds.series_len() as f64)),
        ("band", Json::num(band as f64)),
        ("k", Json::num(K as f64)),
        ("records", Json::Arr(records)),
    ]);
    if std::fs::write("BENCH_SHARD.json", out.to_pretty()).is_ok() {
        println!("wrote BENCH_SHARD.json");
    }
}
