//! Repo-invariant lints: `cargo xtask lint`.
//!
//! Four rules, documented in `EXPERIMENTS.md` §Correctness toolchain and
//! run as a blocking CI job:
//!
//! 1. **partial-cmp-unwrap** — no `.partial_cmp(..)` followed by
//!    `.unwrap()` anywhere (including across line breaks): a NaN turns
//!    the ordering into a panic at the call site.  Use `f64::total_cmp`
//!    or an explicit NaN policy (`unwrap_or(..)` is fine).
//! 2. **hot-alloc** — no allocating `Vec::new()` / `vec![..]` /
//!    `.to_vec()` inside the DP kernel hot paths: `rust/src/measures/`
//!    (minus `workspace.rs`, which *is* the scratch allocator, and
//!    `spec.rs`, which is config/serialization) plus
//!    `rust/src/search/early.rs`, `rust/src/search/lanes.rs`, and the
//!    per-sample streaming monitor `rust/src/stream/`.  Kernels must
//!    draw scratch from
//!    `DpWorkspace`.  Documented reference implementations opt out with
//!    `// lint:allow(hot-alloc): <why>` on the same line or up to two
//!    lines above (one marker line covers a two-line allocation pair).
//!    `#[cfg(test)]` mod regions are exempt.
//! 3. **safety-comment** — every `unsafe` token (block or impl) must
//!    have a `// SAFETY:` comment on the same line or within the six
//!    raw lines above it.  Pairs with `#![deny(unsafe_op_in_unsafe_fn)]`
//!    in `lib.rs`: each unsafe block carries a local proof obligation.
//! 4. **error-coverage** — every `Error` variant must be matched as
//!    `Error::<Variant>` inside `Error::code()`, and every wire-code
//!    string emitted there (plus the wire-only `unsupported_proto`)
//!    must appear in `rust/src/coordinator/server.rs` — i.e. in its
//!    protocol error table.
//!
//! The scanner is plain offset/line analysis over comment- and
//! string-sanitized source — no rustc plumbing, no external crates —
//! which is exactly enough for these shapes and keeps the lint runnable
//! offline.  The sanitizer blanks comments, string/char literals, and
//! raw strings with spaces (byte offsets and newlines preserved), so
//! commented-out or quoted code can never trip a rule, and brace/paren
//! counting can't be skewed by literals.
//!
//! `cargo xtask lint --self-test` (and `cargo test -p xtask`) runs the
//! rules against embedded fixtures with seeded violations, so a
//! regressed rule fails loudly instead of silently passing the tree.
//!
//! Known limits, accepted for a line-level lint: the SAFETY window can
//! be satisfied by a nearby unrelated comment, and `#[cfg(test)]`
//! detection expects the attribute on its own line (the repo style).

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    let refs: Vec<&str> = args.iter().map(String::as_str).collect();
    match refs.as_slice() {
        ["lint"] => run_lint(),
        ["lint", "--self-test"] => run_self_test(),
        _ => {
            eprintln!("usage: cargo xtask lint [--self-test]");
            ExitCode::from(2)
        }
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn violation(file: &str, line: usize, rule: &'static str, msg: String) -> Violation {
    Violation {
        file: file.to_string(),
        line,
        rule,
        msg,
    }
}

fn repo_root() -> PathBuf {
    // xtask lives at <repo>/rust/xtask.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("xtask manifest has no grandparent")
        .to_path_buf()
}

/// Every `.rs` file under `rust/src` and `rust/tests`, sorted for
/// deterministic reports.  `rust/xtask` (fixture strings) and
/// `rust/fuzz` (its own workspace) are deliberately out of scope.
fn rust_sources(root: &Path) -> Vec<PathBuf> {
    let mut files = Vec::new();
    let mut stack = vec![root.join("rust/src"), root.join("rust/tests")];
    while let Some(dir) = stack.pop() {
        let entries = match fs::read_dir(&dir) {
            Ok(e) => e,
            Err(err) => panic!("read_dir {}: {err}", dir.display()),
        };
        for entry in entries {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    files
}

fn rel_of(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .into_owned()
}

fn hot_alloc_applies(rel: &str) -> bool {
    if rel == "rust/src/search/early.rs" || rel == "rust/src/search/lanes.rs" {
        return true;
    }
    // the streaming monitor runs its cascade per ingested sample — the
    // hottest path in the tree; every steady-state buffer must come
    // from the session's reusable scratch
    if rel.starts_with("rust/src/stream/") {
        return true;
    }
    match rel.strip_prefix("rust/src/measures/") {
        Some(name) => name != "workspace.rs" && name != "spec.rs",
        None => false,
    }
}

fn run_lint() -> ExitCode {
    let root = repo_root();
    let files = rust_sources(&root);
    let mut violations = Vec::new();
    for path in &files {
        let raw = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(err) => panic!("read {}: {err}", path.display()),
        };
        let san = sanitize(&raw);
        let rel = rel_of(&root, path);
        violations.extend(check_partial_cmp(&rel, &san));
        violations.extend(check_safety(&rel, &raw, &san));
        if hot_alloc_applies(&rel) {
            violations.extend(check_hot_alloc(&rel, &raw, &san));
        }
    }
    violations.extend(check_error_coverage(&root));

    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    for v in &violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    if violations.is_empty() {
        eprintln!("xtask lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Sanitizer
// ---------------------------------------------------------------------------

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Blank comments, string/char literals (delimiters included), and raw
/// strings with spaces.  Newlines and byte offsets are preserved, so
/// line numbers computed on the sanitized text match the source.
fn sanitize(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                while i < n && b[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let mut depth = 1usize;
                out[i] = b' ';
                out[i + 1] = b' ';
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else {
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'r' | b'b' if is_raw_string_start(b, i) => i = blank_raw_string(b, &mut out, i),
            b'"' => i = blank_string(b, &mut out, i),
            b'\'' => i = blank_char_or_lifetime(b, &mut out, i),
            _ => i += 1,
        }
    }
    String::from_utf8(out).expect("sanitizer blanked through a multi-byte char")
}

/// `r"`, `r#"`, `br"`, ... with a non-identifier byte before (so plain
/// identifiers ending or starting in `r`/`b` don't trigger).
fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    if i > 0 && is_ident(b[i - 1]) {
        return false;
    }
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return false;
    }
    j += 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn blank_raw_string(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let n = b.len();
    let mut i = start;
    if b[i] == b'b' {
        out[i] = b' ';
        i += 1;
    }
    out[i] = b' '; // the `r`
    i += 1;
    let mut hashes = 0;
    while b[i] == b'#' {
        out[i] = b' ';
        i += 1;
        hashes += 1;
    }
    out[i] = b' '; // opening quote
    i += 1;
    while i < n {
        if b[i] == b'"' && b[i + 1..].iter().take(hashes).all(|&c| c == b'#') && i + hashes < n {
            out[i..i + 1 + hashes].fill(b' ');
            return i + 1 + hashes;
        }
        if b[i] != b'\n' {
            out[i] = b' ';
        }
        i += 1;
    }
    i
}

fn blank_string(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let n = b.len();
    out[start] = b' ';
    let mut i = start + 1;
    while i < n {
        match b[i] {
            b'\\' if i + 1 < n => {
                out[i] = b' ';
                if b[i + 1] != b'\n' {
                    out[i + 1] = b' ';
                }
                i += 2;
            }
            b'"' => {
                out[i] = b' ';
                return i + 1;
            }
            b'\n' => i += 1,
            _ => {
                out[i] = b' ';
                i += 1;
            }
        }
    }
    i
}

/// Distinguish `'x'` / `'\n'` char literals (blanked) from `'a`
/// lifetimes (kept).
fn blank_char_or_lifetime(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let n = b.len();
    if start + 2 < n && b[start + 1] == b'\\' {
        // `'\X'` (incl. `'\\'`, `'\''`, `'\u{..}'`): the byte after the
        // backslash is always part of the escape, then scan for the
        // closing quote.
        out[start] = b' ';
        out[start + 1] = b' ';
        out[start + 2] = b' ';
        let mut i = start + 3;
        while i < n {
            if b[i] == b'\'' {
                out[i] = b' ';
                return i + 1;
            }
            if b[i] != b'\n' {
                out[i] = b' ';
            }
            i += 1;
        }
        return i;
    }
    if start + 2 < n && b[start + 2] == b'\'' {
        out[start] = b' ';
        out[start + 1] = b' ';
        out[start + 2] = b' ';
        return start + 3;
    }
    start + 1 // lifetime: leave as-is
}

// ---------------------------------------------------------------------------
// Offset helpers
// ---------------------------------------------------------------------------

fn line_starts(src: &str) -> Vec<usize> {
    let mut starts = vec![0];
    for (i, c) in src.bytes().enumerate() {
        if c == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

fn line_of(starts: &[usize], offset: usize) -> usize {
    match starts.binary_search(&offset) {
        Ok(k) => k + 1,
        Err(k) => k,
    }
}

fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if from >= hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

/// Substring match where the byte before the match is not an
/// identifier byte (`LocVec::new` must not match `Vec::new`).
fn contains_bounded(line: &str, pat: &str) -> bool {
    let b = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find(pat).map(|p| p + from) {
        if p == 0 || !is_ident(b[p - 1]) {
            return true;
        }
        from = p + 1;
    }
    false
}

// ---------------------------------------------------------------------------
// Rule 1: partial-cmp-unwrap
// ---------------------------------------------------------------------------

fn check_partial_cmp(rel: &str, san: &str) -> Vec<Violation> {
    let b = san.as_bytes();
    let starts = line_starts(san);
    let needle = b".partial_cmp(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = find_from(b, needle, from) {
        from = p + needle.len();
        // Balance parens from the opening `(` (strings are blanked, so
        // only code parens count), then look across any whitespace for
        // a `.unwrap(` continuation.
        let mut i = p + needle.len() - 1;
        let mut depth = 0i64;
        while i < b.len() {
            match b[i] {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i < b.len() && b[i] == b'.' {
            i += 1;
            while i < b.len() && b[i].is_ascii_whitespace() {
                i += 1;
            }
            if b[i..].starts_with(b"unwrap") {
                let mut j = i + "unwrap".len();
                while j < b.len() && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                if j < b.len() && b[j] == b'(' {
                    out.push(violation(
                        rel,
                        line_of(&starts, p),
                        "partial-cmp-unwrap",
                        "`.partial_cmp(..).unwrap()` panics on NaN; \
                         use `total_cmp` or an explicit NaN policy"
                            .to_string(),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: hot-alloc
// ---------------------------------------------------------------------------

const HOT_ALLOC_MARKER: &str = "lint:allow(hot-alloc)";

fn alloc_hit(san_line: &str) -> Option<&'static str> {
    if contains_bounded(san_line, "Vec::new(") {
        return Some("Vec::new()");
    }
    if contains_bounded(san_line, "vec!") {
        return Some("vec![..]");
    }
    if san_line.contains(".to_vec(") {
        return Some(".to_vec()");
    }
    None
}

/// Mark the lines belonging to `#[cfg(test)]` mod regions, by brace
/// balance over the sanitized lines (string/comment braces are gone).
fn test_line_mask(raw_lines: &[&str], san_lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; san_lines.len()];
    let mut i = 0;
    while i < raw_lines.len() {
        if raw_lines[i].trim() != "#[cfg(test)]" {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < san_lines.len() {
            mask[j] = true;
            for c in san_lines[j].bytes() {
                match c {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

fn check_hot_alloc(rel: &str, raw: &str, san: &str) -> Vec<Violation> {
    let raw_lines: Vec<&str> = raw.lines().collect();
    let san_lines: Vec<&str> = san.lines().collect();
    let in_test = test_line_mask(&raw_lines, &san_lines);
    let mut out = Vec::new();
    for (idx, san_line) in san_lines.iter().enumerate() {
        if in_test[idx] {
            continue;
        }
        let Some(what) = alloc_hit(san_line) else {
            continue;
        };
        let lo = idx.saturating_sub(2);
        if raw_lines[lo..=idx]
            .iter()
            .any(|l| l.contains(HOT_ALLOC_MARKER))
        {
            continue;
        }
        out.push(violation(
            rel,
            idx + 1,
            "hot-alloc",
            format!(
                "{what} allocates in a DP hot path; draw scratch from \
                 `DpWorkspace` or annotate `// lint:allow(hot-alloc): <why>`"
            ),
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: safety-comment
// ---------------------------------------------------------------------------

/// `unsafe` as a whole word on a sanitized line (so `unsafe_op_in_unsafe_fn`
/// and comment/string mentions don't count).
fn has_unsafe_token(san_line: &str) -> bool {
    let b = san_line.as_bytes();
    let mut from = 0;
    while let Some(p) = san_line[from..].find("unsafe").map(|p| p + from) {
        let pre = p == 0 || !is_ident(b[p - 1]);
        let post = p + 6 >= b.len() || !is_ident(b[p + 6]);
        if pre && post {
            return true;
        }
        from = p + 6;
    }
    false
}

fn check_safety(rel: &str, raw: &str, san: &str) -> Vec<Violation> {
    let raw_lines: Vec<&str> = raw.lines().collect();
    let san_lines: Vec<&str> = san.lines().collect();
    let mut out = Vec::new();
    for (idx, san_line) in san_lines.iter().enumerate() {
        if !has_unsafe_token(san_line) {
            continue;
        }
        let lo = idx.saturating_sub(6);
        if raw_lines[lo..=idx].iter().any(|l| l.contains("SAFETY:")) {
            continue;
        }
        out.push(violation(
            rel,
            idx + 1,
            "safety-comment",
            "`unsafe` without a `// SAFETY:` comment on the same line \
             or within the six lines above"
                .to_string(),
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: error-coverage
// ---------------------------------------------------------------------------

fn check_error_coverage(root: &Path) -> Vec<Violation> {
    let err_path = root.join("rust/src/error.rs");
    let srv_path = root.join("rust/src/coordinator/server.rs");
    let err_raw = fs::read_to_string(&err_path).expect("read error.rs");
    let srv_raw = fs::read_to_string(&srv_path).expect("read server.rs");
    error_coverage_core(&err_raw, &srv_raw)
}

fn error_coverage_core(err_raw: &str, srv_raw: &str) -> Vec<Violation> {
    const ERR_FILE: &str = "rust/src/error.rs";
    const SRV_FILE: &str = "rust/src/coordinator/server.rs";
    let err_san = sanitize(err_raw);
    let mut out = Vec::new();

    let variants = enum_variants(&err_san, "Error");
    if variants.is_empty() {
        out.push(violation(
            ERR_FILE,
            1,
            "error-coverage",
            "could not locate `enum Error` variants".to_string(),
        ));
        return out;
    }
    let Some((body_start, body_end)) = fn_body_span(&err_san, "fn code(") else {
        out.push(violation(
            ERR_FILE,
            1,
            "error-coverage",
            "could not locate `fn code(` body".to_string(),
        ));
        return out;
    };
    let code_san = &err_san[body_start..body_end];
    let code_raw = &err_raw[body_start..body_end];
    let code_line = line_of(&line_starts(&err_san), body_start);

    for (name, line) in &variants {
        if !code_san.contains(&format!("Error::{name}")) {
            out.push(violation(
                ERR_FILE,
                *line,
                "error-coverage",
                format!("variant `{name}` is not mapped in `Error::code()`"),
            ));
        }
    }

    // Every string returned by code() — the wire codes, plus the
    // incidental `"op"` guard literal, which matches trivially — and the
    // wire-only `unsupported_proto` must appear in server.rs (its
    // protocol error table documents each).
    let mut codes = string_literals(code_raw);
    codes.push("unsupported_proto".to_string());
    codes.sort();
    codes.dedup();
    for code in &codes {
        if !srv_raw.contains(code.as_str()) {
            out.push(violation(
                SRV_FILE,
                code_line,
                "error-coverage",
                format!("wire code `{code}` is not documented in server.rs"),
            ));
        }
    }
    out
}

/// Variant names (with line numbers) of `enum <name>`: lines at brace
/// depth 1 inside the enum body whose first character is uppercase.
fn enum_variants(san: &str, name: &str) -> Vec<(String, usize)> {
    let Some(decl) = san.find(&format!("enum {name}")) else {
        return Vec::new();
    };
    let Some(open) = san[decl..].find('{').map(|p| p + decl) else {
        return Vec::new();
    };
    let starts = line_starts(san);
    let mut variants = Vec::new();
    let mut depth = 1i64;
    let b = san.as_bytes();
    let mut i = open + 1;
    let mut at_line_head = false;
    while i < b.len() && depth > 0 {
        match b[i] {
            b'{' | b'(' | b'[' => depth += 1,
            b'}' | b')' | b']' => depth -= 1,
            b'\n' => at_line_head = true,
            c if c.is_ascii_whitespace() => {}
            c => {
                if at_line_head && depth == 1 && c.is_ascii_uppercase() {
                    let mut j = i;
                    while j < b.len() && is_ident(b[j]) {
                        j += 1;
                    }
                    variants.push((san[i..j].to_string(), line_of(&starts, i)));
                }
                at_line_head = false;
            }
        }
        i += 1;
    }
    variants
}

/// Byte span of the body of the first function whose header matches
/// `header` (e.g. `"fn code("`), exclusive of the outer braces.
fn fn_body_span(san: &str, header: &str) -> Option<(usize, usize)> {
    let decl = san.find(header)?;
    let open = san[decl..].find('{').map(|p| p + decl)?;
    let b = san.as_bytes();
    let mut depth = 0i64;
    for (off, &c) in b[open..].iter().enumerate() {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((open + 1, open + off));
                }
            }
            _ => {}
        }
    }
    None
}

/// String literals in `src`, skipping `//` comments.  (Used on raw
/// text, where quotes still exist.)
fn string_literals(src: &str) -> Vec<String> {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'"' => {
                let start = i + 1;
                i += 1;
                while i < n && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1;
                    }
                    i += 1;
                }
                out.push(src[start..i.min(n)].to_string());
                i += 1;
            }
            _ => i += 1,
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Self-test fixtures: seeded violations that must keep firing.
// ---------------------------------------------------------------------------

const FIX_PARTIAL_CMP: &str = r#"
fn bad_single(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap()
}
fn bad_multiline(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b)
        .unwrap()
}
fn ok_total(a: f64, b: f64) -> std::cmp::Ordering {
    a.total_cmp(&b)
}
fn ok_policy(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal)
}
impl PartialOrd for Wrapper {
    fn partial_cmp(&self, other: &Wrapper) -> Option<std::cmp::Ordering> {
        None
    }
}
// commented out, must not fire: a.partial_cmp(&b).unwrap()
const S: &str = "a.partial_cmp(&b).unwrap()";
"#;

const FIX_HOT_ALLOC: &str = r#"
fn kernel(n: usize) -> Vec<f64> {
    let mut buf = Vec::new();
    let tmp = vec![0.0; n];
    let copy = tmp.to_vec();
    // lint:allow(hot-alloc): seeded fixture escape hatch.
    let first = vec![0.0; n];
    let second = Vec::new();
    let quoted = "vec![in a string]";
    let custom = LocVec::new();
    buf
}
#[cfg(test)]
mod tests {
    #[test]
    fn in_test_region_is_exempt() {
        let v = vec![1.0, 2.0];
    }
}
"#;

const FIX_HOT_ALLOC_LANE: &str = r#"
fn lane_kernel(t: usize, lanes: usize) -> f64 {
    let mut lane_vals = vec![0.0; t * lanes];
    let mut ubs = Vec::new();
    // lint:allow(hot-alloc): fixture escape hatch for lane scratch.
    let allowed = vec![0.0; lanes];
    let mut acc = 0.0;
    for &u in &allowed {
        acc += u;
    }
    let tails = allowed.to_vec();
    lane_vals[0] + ubs.drain(..).sum::<f64>() + tails[0] + acc
}
"#;

const FIX_HOT_ALLOC_STREAM: &str = r#"
fn push(&mut self, v: f64) -> Option<Report> {
    let window = self.ring.to_vec();
    let mut upper = Vec::new();
    let staged = vec![0.0; self.t];
    // lint:allow(hot-alloc): fixture escape hatch for staging scratch.
    let allowed = vec![0.0; self.t];
    Some(Report { window, upper, staged, allowed })
}
"#;

const FIX_SAFETY: &str = r#"
struct P(*const u8);
unsafe impl Send for P {}
// SAFETY: the pointer is never dereferenced on other threads.
unsafe impl Sync for P {}
fn covered(p: *const u8) -> u8 {
    // SAFETY: caller guarantees `p` is valid for reads.
    unsafe { *p }
}

fn spacer_one() {}
fn spacer_two() {}
fn spacer_three() {}
fn spacer_four() {}

fn uncovered(p: *const u8) -> u8 {
    unsafe { *p }
}
fn not_the_keyword() {
    let unsafe_adjacent = 1;
    let _ = unsafe_adjacent;
}
"#;

const FIX_ERROR_OK: &str = r#"
pub enum Error {
    Io(std::io::Error),
    Parse { msg: String },
}
impl Error {
    pub fn code(&self) -> &'static str {
        match self {
            Error::Io(_) => "internal",
            Error::Parse { .. } => "bad_json",
        }
    }
}
"#;

const FIX_ERROR_BAD: &str = r#"
pub enum Error {
    Io(std::io::Error),
    Parse { msg: String },
    Orphan,
}
impl Error {
    pub fn code(&self) -> &'static str {
        match self {
            Error::Io(_) => "internal",
            Error::Parse { .. } => "undocumented_code",
            _ => "internal",
        }
    }
}
"#;

const FIX_SERVER: &str = r#"
//! | code | meaning |
//! | `internal` | internal failure |
//! | `bad_json` | malformed envelope |
//! | `unsupported_proto` | unknown proto version |
"#;

struct SelfTestCase {
    name: &'static str,
    expect: usize,
    found: usize,
}

fn self_test_cases() -> Vec<SelfTestCase> {
    let partial = check_partial_cmp("fixture.rs", &sanitize(FIX_PARTIAL_CMP));
    let hot = check_hot_alloc("fixture.rs", FIX_HOT_ALLOC, &sanitize(FIX_HOT_ALLOC));
    let lane = check_hot_alloc(
        "fixture_lane.rs",
        FIX_HOT_ALLOC_LANE,
        &sanitize(FIX_HOT_ALLOC_LANE),
    );
    let stream = check_hot_alloc(
        "fixture_stream.rs",
        FIX_HOT_ALLOC_STREAM,
        &sanitize(FIX_HOT_ALLOC_STREAM),
    );
    let safety = check_safety("fixture.rs", FIX_SAFETY, &sanitize(FIX_SAFETY));
    let err_ok = error_coverage_core(FIX_ERROR_OK, FIX_SERVER);
    let err_bad = error_coverage_core(FIX_ERROR_BAD, FIX_SERVER);
    vec![
        SelfTestCase {
            name: "partial-cmp-unwrap fires on single- and multi-line",
            expect: 2,
            found: partial.len(),
        },
        SelfTestCase {
            name: "hot-alloc fires on Vec::new/vec!/.to_vec, honors allow",
            expect: 3,
            found: hot.len(),
        },
        SelfTestCase {
            name: "hot-alloc fires on lane-kernel scratch, honors allow",
            expect: 3,
            found: lane.len(),
        },
        SelfTestCase {
            name: "hot-alloc fires on per-sample stream push scratch, honors allow",
            expect: 3,
            found: stream.len(),
        },
        SelfTestCase {
            name: "safety-comment fires on uncovered unsafe only",
            expect: 2,
            found: safety.len(),
        },
        SelfTestCase {
            name: "error-coverage passes a fully mapped enum",
            expect: 0,
            found: err_ok.len(),
        },
        SelfTestCase {
            name: "error-coverage fires on orphan variant + undocumented code",
            expect: 2,
            found: err_bad.len(),
        },
    ]
}

fn run_self_test() -> ExitCode {
    let mut failed = 0;
    for case in self_test_cases() {
        let ok = case.expect == case.found;
        println!(
            "{} {} (expected {}, found {})",
            if ok { "PASS" } else { "FAIL" },
            case.name,
            case.expect,
            case.found
        );
        if !ok {
            failed += 1;
        }
    }
    if failed == 0 {
        eprintln!("xtask lint --self-test: all rules fire");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint --self-test: {failed} rule(s) regressed");
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_fire_expected_counts() {
        for case in self_test_cases() {
            assert_eq!(case.expect, case.found, "{}", case.name);
        }
    }

    #[test]
    fn partial_cmp_violations_carry_line_numbers() {
        let v = check_partial_cmp("f.rs", &sanitize(FIX_PARTIAL_CMP));
        let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![3, 6]);
    }

    #[test]
    fn hot_alloc_skips_strings_and_bounded_idents() {
        let v = check_hot_alloc("f.rs", FIX_HOT_ALLOC, &sanitize(FIX_HOT_ALLOC));
        let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
        // Vec::new, vec!, .to_vec — not the allowed pair, the quoted
        // string, or `LocVec::new`.
        assert_eq!(lines, vec![3, 4, 5]);
    }

    #[test]
    fn hot_alloc_lane_fixture_fires_outside_marker_window() {
        let v = check_hot_alloc(
            "f.rs",
            FIX_HOT_ALLOC_LANE,
            &sanitize(FIX_HOT_ALLOC_LANE),
        );
        let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
        // vec! scratch, Vec::new ubs, and the .to_vec past the marker's
        // two-line window — not the allowed vec! right under the marker.
        assert_eq!(lines, vec![3, 4, 11]);
    }

    #[test]
    fn hot_alloc_stream_fixture_fires_outside_marker_window() {
        let v = check_hot_alloc(
            "f.rs",
            FIX_HOT_ALLOC_STREAM,
            &sanitize(FIX_HOT_ALLOC_STREAM),
        );
        let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
        // .to_vec window copy, Vec::new envelope, vec! staging — not
        // the allowed vec! right under the marker.
        assert_eq!(lines, vec![3, 4, 5]);
    }

    #[test]
    fn hot_alloc_scope_covers_lane_kernels() {
        assert!(hot_alloc_applies("rust/src/search/lanes.rs"));
        assert!(hot_alloc_applies("rust/src/search/early.rs"));
        assert!(hot_alloc_applies("rust/src/measures/dtw.rs"));
        // the per-sample streaming monitor is all hot path
        assert!(hot_alloc_applies("rust/src/stream/mod.rs"));
        assert!(hot_alloc_applies("rust/src/stream/rws.rs"));
        // the engine assembles groups (cold per query), workspace/spec
        // are the arena and config layers — all out of scope
        assert!(!hot_alloc_applies("rust/src/search/engine.rs"));
        assert!(!hot_alloc_applies("rust/src/measures/workspace.rs"));
        assert!(!hot_alloc_applies("rust/src/measures/spec.rs"));
    }

    #[test]
    fn safety_window_is_same_line_or_six_above() {
        let v = check_safety("f.rs", FIX_SAFETY, &sanitize(FIX_SAFETY));
        let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![3, 17]);
    }

    #[test]
    fn sanitizer_preserves_offsets() {
        let src = "let a = \"x\"; // trailing\nlet b = 'y';\n";
        let san = sanitize(src);
        assert_eq!(src.len(), san.len());
        assert_eq!(
            src.bytes().filter(|&c| c == b'\n').count(),
            san.bytes().filter(|&c| c == b'\n').count()
        );
        assert!(!san.contains("trailing"));
        assert!(!san.contains('\''));
    }

    #[test]
    fn sanitizer_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"vec![1]\"#; }";
        let san = sanitize(src);
        assert!(san.contains("<'a>"), "lifetimes survive: {san}");
        assert!(!san.contains("vec!"), "raw string blanked: {san}");
    }

    #[test]
    fn enum_variant_extraction_sees_all_shapes() {
        let san = sanitize(FIX_ERROR_BAD);
        let names: Vec<String> = enum_variants(&san, "Error")
            .into_iter()
            .map(|(n, _)| n)
            .collect();
        assert_eq!(names, vec!["Io", "Parse", "Orphan"]);
    }

    #[test]
    fn lint_is_clean_on_the_repo_tree() {
        // The blocking CI invariant, runnable locally too: the checked-in
        // tree has zero violations.
        let root = repo_root();
        let mut violations = Vec::new();
        for path in rust_sources(&root) {
            let raw = fs::read_to_string(&path).expect("read source");
            let san = sanitize(&raw);
            let rel = rel_of(&root, &path);
            violations.extend(check_partial_cmp(&rel, &san));
            violations.extend(check_safety(&rel, &raw, &san));
            if hot_alloc_applies(&rel) {
                violations.extend(check_hot_alloc(&rel, &raw, &san));
            }
        }
        violations.extend(check_error_coverage(&root));
        assert!(
            violations.is_empty(),
            "tree has lint violations: {violations:#?}"
        );
    }
}
