//! Fuzz the TCP line protocol (v1 bare ops + v2 envelope) through the
//! transport-free `server::dispatch_line` entry — the exact dispatch a
//! socket connection performs, minus the socket.
//!
//! Contract under test: ANY input line produces a JSON reply (typed
//! error replies for malformed input — `bad_json` / `bad_request` /
//! `bad_input` / `unknown_op` / `unsupported_proto`), never a panic,
//! stack overflow, or unbounded allocation.  Findings this target
//! already produced, landed as fixes + regressions:
//!
//! - unbounded parser recursion: `[[[[`…×100k overflowed the stack —
//!   fixed with `util::json::MAX_PARSE_DEPTH`, regression
//!   `parse_depth_is_bounded` + the protocol malformed-envelope matrix;
//! - unbounded `register_grid` materialization: a huge `t` allocated
//!   O(t²) cells before any cap — v1 now routes through the same
//!   `MAX_INLINE_GRID_CELLS` validation as the v2 spec path.
//!
//! One long-lived coordinator (no PJRT, no store) serves every input:
//! state accumulated across inputs (registered grids/measures/indexes)
//! only widens coverage into the key-addressed ops.  Inputs are capped
//! by libfuzzer's default `-max_len`, so `register_index` payloads stay
//! small.
//!
//! Seed corpus: `corpus/fuzz_wire/` holds one valid line per op family
//! on both protocol versions (see `ci/make_wire_corpus.py`).
//!
//! Run: `cd rust && cargo +nightly fuzz run fuzz_wire`.  CI runs a
//! bounded `-runs` smoke on every push (`fuzz-smoke` job); findings are
//! promoted to `tests/integration_protocol.rs`.

#![no_main]

use std::sync::OnceLock;

use libfuzzer_sys::fuzz_target;
use spdtw::config::CoordinatorConfig;
use spdtw::coordinator::{server, Coordinator};

static COORD: OnceLock<Coordinator> = OnceLock::new();

fuzz_target!(|data: &[u8]| {
    if let Ok(line) = std::str::from_utf8(data) {
        let coord = COORD.get_or_init(|| {
            let mut cfg = CoordinatorConfig::default();
            // keep the shared dispatcher lean: no disk store, tiny pool
            cfg.warm_start = false;
            Coordinator::start(cfg, None).expect("start fuzz coordinator")
        });
        let _ = server::dispatch_line(line, coord);
    }
});
