#!/usr/bin/env python3
"""Streaming smoke test (CI).

Drives both streaming surfaces end to end with the release binary:

1. `spdtw monitor` over a synthetic drifting stream from `--input`,
   once on the exact path (report lines must say `path=exact`, the
   summary must show no recall because nothing was audited) and once
   with `--rws` at a candidate budget covering the whole corpus with
   every window audited (lines must say `path=approx`, carry
   `recall=1.000`, and the summary must measure recall@k = 1.0000).

2. The `stream_*` wire ops against a live `spdtw serve`: an exact
   session whose `stream_matches` neighbors equal the batch `search`
   op over the same window, and an `rws` session that is flagged
   `approx` and reports its measured recall — then clean shutdown over
   the wire.

Usage: python3 ci/stream_smoke.py [path/to/spdtw]
"""

import json
import math
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

BIN = sys.argv[1] if len(sys.argv) > 1 else "target/release/spdtw"
ADDR = ("127.0.0.1", 7990)


def expect(cond, what, detail=""):
    if not cond:
        raise SystemExit(f"FAIL: {what}: {detail}")


def call(req, attempts=40):
    """One request/reply line against the serve process, retrying
    connect while it is still booting."""
    last = None
    for _ in range(attempts):
        try:
            with socket.create_connection(ADDR, timeout=10) as s:
                f = s.makefile("rw", encoding="utf-8")
                f.write(json.dumps(req) + "\n")
                f.flush()
                return json.loads(f.readline())
        except OSError as e:
            last = e
            time.sleep(0.25)
    raise SystemExit(f"cannot reach {ADDR}: {last}")


def drifting_stream(n):
    """A slow ramp with a wobble: every window differs from the last,
    so the monitor keeps re-ranking neighbors as the source drifts."""
    return [0.1 * i + math.sin(0.7 * i) for i in range(n)]


def run_monitor(extra, inp):
    cmd = [
        BIN, "monitor", "SyntheticControl",
        "--max-train", "8", "--max-test", "2", "--k", "2",
        "--input", str(inp), "--report-every", "1", "--max-windows", "5",
    ] + extra
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=300)
    expect(r.returncode == 0, f"monitor exited {r.returncode}", r.stderr or r.stdout)
    return r.stdout


def check_monitor_cli(inp):
    # exact path: the default, and the report must say so on every line
    out = run_monitor([], inp)
    headers = [l for l in out.splitlines() if l.startswith("monitor ")]
    expect(headers and "path=exact" in headers[0], "exact header", out)
    match_lines = [l for l in out.splitlines() if l.startswith("window ")]
    expect(len(match_lines) == 5, "5 reported windows", out)
    for l in match_lines:
        expect("path=exact" in l and "idx=" in l and "dist=" in l, "exact match line", l)
        expect("recall=" not in l, "exact path never reports recall", l)
    expect("recall@k (audited): n/a" in out, "no audits on the exact path", out)

    # approximate path: candidate budget == corpus (8), every window
    # audited, so the measured recall must be exactly 1.0
    out = run_monitor(
        ["--rws", "--rws-candidates", "8", "--audit-every", "1"], inp
    )
    expect("path=approx(rws)" in out, "approx header", out)
    match_lines = [l for l in out.splitlines() if l.startswith("window ")]
    expect(len(match_lines) == 5, "5 reported windows", out)
    for l in match_lines:
        expect("path=approx" in l, "approx flagged on every line", l)
        expect("recall=1.000" in l, "audited window recall", l)
    expect("recall@k (audited): 1.0000" in out, "measured recall@k", out)

    # tuning flags without --rws must refuse, not silently run approx
    r = subprocess.run(
        [BIN, "monitor", "SyntheticControl", "--rws-candidates", "4",
         "--input", str(inp)],
        capture_output=True, text=True, timeout=300,
    )
    expect(r.returncode != 0, "rws tuning without --rws is an error", r.stdout)
    print("monitor CLI OK: exact + approx(rws, recall=1.0) + flag guard")


def check_wire():
    reg = call({
        "op": "register_index", "band": 1,
        "series": [[0, 0, 0, 0], [5, 5, 5, 5], [1, 2, 3, 4], [4, 3, 2, 1]],
        "labels": [0, 1, 0, 1],
    })
    expect(reg.get("ok") is True, "register_index", reg)
    idx = reg["index"]

    # exact session over a drifting ramp; the last full window is the
    # final 4 samples, and stream_matches must equal batch search on it
    r = call({"op": "stream_open", "index": idx, "k": 2})
    expect(r.get("ok") is True and r.get("approx") is False, "exact open", r)
    expect(r.get("t") == 4, "window length from the index", r)
    s = r["stream"]
    ramp = [round(v, 3) for v in drifting_stream(9)]
    r = call({"op": "stream_push", "stream": s, "values": ramp})
    expect(r.get("ok") is True and r.get("windows") == 6, "push ramp", r)
    m = call({"op": "stream_matches", "stream": s})
    expect(m.get("approx") is False and m.get("window_start") == 5, "exact matches", m)
    want = call({"op": "search", "index": idx, "k": 2, "x": ramp[-4:]})
    expect(
        [(n["dist"], n["idx"]) for n in m["neighbors"]]
        == [(n["dist"], n["idx"]) for n in want["neighbors"]],
        "stream_matches == batch search on the same window",
        (m, want),
    )
    r = call({"op": "stream_close", "stream": s})
    expect(r.get("ok") is True and r.get("windows") == 6, "close exact", r)

    # approximate session: flagged, and recall measured at full budget
    r = call({
        "op": "stream_open", "index": idx, "k": 2,
        "rws": {"d": 2, "candidates": 4, "audit_every": 1},
    })
    expect(r.get("approx") is True, "rws open is flagged", r)
    s = r["stream"]
    r = call({"op": "stream_push", "stream": s, "values": ramp})
    expect(r.get("ok") is True, "push ramp (rws)", r)
    m = call({"op": "stream_matches", "stream": s})
    expect(m.get("approx") is True, "rws matches flagged", m)
    expect(m.get("recall_at_k") == 1.0, "full budget measures recall 1.0", m)
    r = call({"op": "stream_close", "stream": s})
    expect(r.get("recall_at_k") == 1.0, "close reports session recall", r)

    met = call({"op": "metrics"})
    expect(met.get("streams_opened") == 2 and met.get("streams_closed") == 2,
           "stream metrics", met)
    print("wire OK: exact session == batch search, rws flagged with recall=1.0")


def main():
    with tempfile.TemporaryDirectory() as tmp:
        inp = Path(tmp) / "stream.txt"
        vals = drifting_stream(80)
        # comments and comma/whitespace mixing are part of the accepted
        # input grammar — exercise them, not just bare numbers
        lines = ["# synthetic drifting stream"]
        for i in range(0, len(vals), 4):
            lines.append(", ".join(f"{v:.4f}" for v in vals[i:i + 4]) + "  # chunk")
        inp.write_text("\n".join(lines) + "\n")
        check_monitor_cli(inp)

    serve = subprocess.Popen([BIN, "serve", "--addr", f"{ADDR[0]}:{ADDR[1]}"])
    try:
        check_wire()
        r = call({"op": "shutdown"}, attempts=4)
        expect(r.get("ok") is True, "shutdown", r)
        expect(serve.wait(timeout=30) is not None, "serve exited", "")
    finally:
        if serve.poll() is None:
            serve.kill()
    print("stream smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
