#!/usr/bin/env python3
"""Generate the seed corpus for the `fuzz_spix` fuzz target.

Mirrors the `.spix` v1 writer in `rust/src/search/persist.rs`
byte-for-byte (24-byte header: magic "SPIX", version u32, payload-len
u64, FNV-1a-64 checksum u64; little-endian payload: flags u32, then
t/radius/band/n/nnz u64s, labels, series f64 bits, envelopes, optional
grid triples) so the fuzzer starts from inputs that pass the magic /
version / checksum / dimension gates and mutates its way into the
semantic validators instead of spending its budget rediscovering the
header format.

Checked-in outputs live in `rust/fuzz/corpus/fuzz_spix/`; re-run this
script only when the format version bumps.  Deterministic: no RNG, no
timestamps.
"""

import struct
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "fuzz" / "corpus" / "fuzz_spix"

MAGIC = b"SPIX"
VERSION = 1
FLAG_ZNORM = 1 << 0
FLAG_LB_VALID = 1 << 1
FLAG_HAS_GRID = 1 << 2
U64_MAX = (1 << 64) - 1

FNV_INIT = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def fnv1a64(data: bytes) -> int:
    h = FNV_INIT
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & U64_MAX
    return h


def envelopes(series, radius):
    """Sliding min/max envelope over +-radius, exactly bounding the series."""
    t = len(series)
    upper = [max(series[max(0, j - radius) : min(t, j + radius + 1)]) for j in range(t)]
    lower = [min(series[max(0, j - radius) : min(t, j + radius + 1)]) for j in range(t)]
    return upper, lower


def build(flags, t, radius, band, series_list, labels, grid=None):
    payload = bytearray()
    nnz = len(grid) if grid is not None else 0
    payload += struct.pack("<I", flags)
    for dim in (t, radius, band, len(series_list), nnz):
        payload += struct.pack("<Q", dim)
    for label in labels:
        payload += struct.pack("<Q", label)
    for s in series_list:
        assert len(s) == t
        payload += struct.pack(f"<{t}d", *s)
    for s in series_list:
        upper, lower = envelopes(s, radius)
        payload += struct.pack(f"<{t}d", *upper)
        payload += struct.pack(f"<{t}d", *lower)
    if grid is not None:
        for row, col, weight in grid:
            payload += struct.pack("<IId", row, col, weight)
    header = MAGIC + struct.pack("<IQQ", VERSION, len(payload), fnv1a64(bytes(payload)))
    return header + bytes(payload)


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    seeds = {}

    # banded index: band 3 on T=8 (loader invariant: radius == min(band, t-1))
    series = [
        [0.0, 1.0, 4.0, 2.0, -1.0, 3.0, 5.0, 2.0],
        [2.0, 2.0, 0.0, -3.0, 1.0, 1.0, 4.0, 6.0],
    ]
    seeds["banded.spix"] = build(FLAG_LB_VALID, 8, 3, 3, series, [0, 1])

    # z-normalized, unbounded band: radius must equal t-1
    seeds["znorm.spix"] = build(
        FLAG_ZNORM | FLAG_LB_VALID, 4, 3, U64_MAX, [[-1.0, 0.5, 1.5, -1.0]], [2]
    )

    # SP-DTW grid index: unbounded band, unit weights (so lb_valid is
    # admissible), radius >= the grid's max |row-col| offset of 1
    grid = [(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (2, 2, 1.0), (3, 3, 1.0)]
    seeds["grid.spix"] = build(
        FLAG_HAS_GRID | FLAG_LB_VALID, 4, 2, U64_MAX, [[1.0, -2.0, 0.0, 3.0]], [7], grid
    )

    # valid header over an empty payload: exercises the first Reader
    # bounds check ("payload ends mid-field") rather than the header gates
    seeds["header_only.spix"] = MAGIC + struct.pack("<IQQ", VERSION, 0, FNV_INIT)

    for name, data in sorted(seeds.items()):
        (OUT / name).write_bytes(data)
        print(f"{name}: {len(data)} bytes")


if __name__ == "__main__":
    main()
