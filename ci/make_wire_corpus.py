#!/usr/bin/env python3
"""Generate the seed corpus for the `fuzz_wire` fuzz target.

One valid protocol line per file, covering every op family on both
protocol versions (see the protocol tables at the top of
`rust/src/coordinator/server.rs`), so libfuzzer's dictionary-less
mutations start from requests that reach deep into dispatch — key
lookups, spec parsing, series validation — instead of dying at the JSON
parser.  Key-addressed seeds (grid/index/measure `0`) pair with the
register seeds because the fuzz target reuses one coordinator across
inputs.

Checked-in outputs live in `rust/fuzz/corpus/fuzz_wire/`.
Deterministic: no RNG, no timestamps.
"""

from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "fuzz" / "corpus" / "fuzz_wire"

X = "[0.0,1.0,2.5,1.5,0.5,-0.5,1.0,2.0]"
Y = "[1.0,1.5,2.0,0.5,0.0,1.0,3.0,2.5]"

SEEDS = {
    "ping": '{"op":"ping"}',
    "info": '{"op":"info"}',
    "metrics": '{"op":"metrics"}',
    "register_grid": '{"op":"register_grid","t":8,"band":2}',
    "register_grid_full": '{"op":"register_grid","t":8}',
    "spdtw": f'{{"op":"spdtw","grid":0,"x":{X},"y":{Y}}}',
    "spkrdtw": f'{{"op":"spkrdtw","grid":0,"nu":0.5,"x":{X},"y":{Y}}}',
    "register_index": (
        f'{{"op":"register_index","band":2,"series":[{X},{Y}],"labels":[0,1]}}'
    ),
    "search": f'{{"op":"search","index":0,"k":1,"x":{X}}}',
    "batch_search": f'{{"op":"batch_search","index":0,"k":2,"xs":[{X},{Y}]}}',
    "v2_dist": f'{{"proto":2,"id":"d1","op":"dist","measure":{{"kind":"dtw"}},"x":{X},"y":{Y}}}',
    "v2_dist_key": f'{{"proto":2,"op":"dist","measure":0,"x":{X},"y":{Y}}}',
    "v2_kernel": (
        f'{{"proto":2,"op":"kernel","measure":{{"kind":"krdtw","nu":0.5}},"x":{X},"y":{Y}}}'
    ),
    "v2_register_measure": (
        '{"proto":2,"op":"register_measure",'
        '"measure":{"kind":"sakoe_chiba","band_pct":10}}'
    ),
    "v2_register_index_spec": (
        f'{{"proto":2,"op":"register_index","measure":{{"kind":"banded_dtw","band":2}},'
        f'"series":[{X},{Y}],"labels":[0,1]}}'
    ),
    "v2_search": f'{{"proto":2,"id":7,"op":"search","index":0,"k":1,"x":{X}}}',
    "shard_search": f'{{"proto":2,"op":"shard_search","shard":0,"index":0,"k":1,"x":{X}}}',
    "shard_register": (
        f'{{"proto":2,"op":"register_index","shard":0,"global_ids":[0,2],'
        f'"band":2,"series":[{X},{Y}],"labels":[0,1]}}'
    ),
    "stream_open": '{"op":"stream_open","index":0,"k":2}',
    "stream_open_rws": (
        '{"proto":2,"op":"stream_open","index":0,"k":2,'
        '"rws":{"d":4,"candidates":8,"audit_every":4},"idle_timeout_ms":60000}'
    ),
    "stream_push": f'{{"op":"stream_push","stream":0,"values":{X}}}',
    "stream_push_deadline": (
        f'{{"proto":2,"op":"stream_push","stream":0,"values":{Y},"deadline_ms":1000}}'
    ),
    "stream_matches": '{"op":"stream_matches","stream":0}',
    "stream_close": '{"op":"stream_close","stream":0}',
    "unsupported_proto": '{"proto":3,"op":"ping"}',
    "unknown_op": '{"op":"warp_speed"}',
    "shutdown": '{"op":"shutdown"}',
}


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    for name, line in sorted(SEEDS.items()):
        (OUT / f"{name}.txt").write_text(line + "\n")
        print(f"{name}.txt: {len(line)} bytes")


if __name__ == "__main__":
    main()
