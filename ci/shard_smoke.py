#!/usr/bin/env python3
"""Multi-process shard smoke test (CI).

Expects two `spdtw shard-serve` processes (shards 0 and 1 of 2) and one
`spdtw serve --shards ...` front already listening on loopback:

    shard 0: 127.0.0.1:7971      shard 1: 127.0.0.1:7972
    front:   127.0.0.1:7970

Registers a 4-series corpus through the front (round-robin split puts
globals 0,2 on shard 0 and 1,3 on shard 1), runs one exact k-NN query,
checks the merged answer, and shuts all three processes down over the
wire so the CI step can `wait` on them.
"""

import json
import socket
import sys
import time

FRONT = ("127.0.0.1", 7970)
SHARDS = [("127.0.0.1", 7971), ("127.0.0.1", 7972)]


def call(addr, req, attempts=40):
    """One request/reply line against a spdtw server, retrying connect
    while the server is still booting."""
    last = None
    for _ in range(attempts):
        try:
            with socket.create_connection(addr, timeout=10) as s:
                f = s.makefile("rw", encoding="utf-8")
                f.write(json.dumps(req) + "\n")
                f.flush()
                return json.loads(f.readline())
        except OSError as e:
            last = e
            time.sleep(0.25)
    raise SystemExit(f"cannot reach {addr}: {last}")


def expect(cond, what, reply):
    if not cond:
        raise SystemExit(f"FAIL: {what}: {json.dumps(reply)}")


def main():
    # both shards must identify with their role before the front is used
    for sid, addr in enumerate(SHARDS):
        info = call(addr, {"op": "info"})
        expect(info.get("ok") is True, f"shard {sid} info", info)
        expect(info.get("shard_id") == sid, f"shard {sid} reports its id", info)
        expect(info.get("shards_total") == 2, f"shard {sid} fleet size", info)

    info = call(FRONT, {"op": "info"})
    expect(info.get("ok") is True, "front info", info)
    expect(info.get("role") == "front", "front role", info)
    expect(info.get("shards_total") == 2, "front fleet size", info)
    expect(all(s.get("up") for s in info.get("shards", [])), "links up", info)

    reg = call(
        FRONT,
        {
            "proto": 2,
            "id": 1,
            "op": "register_index",
            "name": "smoke",
            "band": 1,
            "series": [[0, 0, 0], [5, 5, 5], [0.1, 0.1, 0.1], [4, 4, 4]],
            "labels": [0, 1, 0, 1],
        },
    )
    expect(reg.get("ok") is True, "register through front", reg)
    expect(reg.get("id") == 1, "v2 id echo", reg)
    expect(reg.get("count") == 4, "total series", reg)
    expect(reg.get("per_shard") == [2, 2], "round-robin split 0,2 / 1,3", reg)

    r = call(
        FRONT,
        {"proto": 2, "id": 2, "op": "search", "index": "smoke", "k": 2, "x": [0, 0, 0]},
    )
    expect(r.get("ok") is True, "search through front", r)
    expect(r.get("shards_ok") == 2 and r.get("shards_total") == 2, "fan-out health", r)
    ns = r.get("neighbors", [])
    expect(len(ns) == 2, "k=2 neighbors", r)
    # exact expected answer: global 0 at distance 0, then global 2 —
    # both live on shard 0, so a wrong merge (or a silently dropped
    # shard) would be visible here
    expect(ns[0].get("dist") == 0 and ns[0].get("idx") == 0, "nearest is global 0", r)
    expect(ns[0].get("label") == 0, "nearest label", r)
    expect(ns[1].get("idx") == 2 and ns[1].get("dist") > 0, "runner-up is global 2", r)

    # clean shutdown over the wire: front first, then both shards, so
    # every `spdtw` serve loop exits and the CI step's `wait` returns
    for addr in [FRONT] + SHARDS:
        r = call(addr, {"op": "shutdown"}, attempts=4)
        expect(r.get("ok") is True, f"shutdown {addr}", r)

    print("shard smoke OK: exact merged answer over 2 shards + front")
    return 0


if __name__ == "__main__":
    sys.exit(main())
