#!/usr/bin/env python3
"""Multi-process chaos smoke test (CI).

Expects a 2-shard fleet where shard 1 acts out ci/chaos_plan.json via
`spdtw shard-serve --fault-plan`, plus a front started with
`--breaker-threshold 2 --probe-interval-ms 200`:

    shard 0: 127.0.0.1:7981      shard 1 (faulted): 127.0.0.1:7982
    front:   127.0.0.1:7980

The plan is a deterministic per-event schedule on shard 1:

    reply  2        delayed 3 s    -> a 500 ms deadline_ms loses, typed
    reply  3        torn mid-line  -> first link failure
    connects 1..12  refused        -> retry fails, breaker opens; the
                                      200 ms probe thread burns the rest
                                      of the window, then recovers

which the script walks through over the wire, asserting all three typed
degradation codes (`deadline_exceeded`, `unavailable`, flagged
`partial`), that no failed reply ever smuggles a neighbor list, and that
the breaker closes again on its own once the shard behaves.
"""

import json
import socket
import sys
import time

FRONT = ("127.0.0.1", 7980)
SHARD0 = ("127.0.0.1", 7981)
SHARD1 = ("127.0.0.1", 7982)


def call(addr, req, attempts=40):
    """One request/reply line against a spdtw server, retrying connect
    while the server is still booting."""
    last = None
    for _ in range(attempts):
        try:
            with socket.create_connection(addr, timeout=20) as s:
                f = s.makefile("rw", encoding="utf-8")
                f.write(json.dumps(req) + "\n")
                f.flush()
                return json.loads(f.readline())
        except OSError as e:
            last = e
            time.sleep(0.25)
    raise SystemExit(f"cannot reach {addr}: {last}")


def expect(cond, what, reply):
    if not cond:
        raise SystemExit(f"FAIL: {what}: {json.dumps(reply)}")


def search(k=2, x=(0, 0, 0), **extra):
    req = {"proto": 2, "op": "search", "index": "chaos", "k": k, "x": list(x)}
    req.update(extra)
    return call(FRONT, req)


def main():
    # 1. topology up, every breaker closed
    info = call(FRONT, {"op": "info"})
    expect(info.get("ok") is True, "front info", info)
    expect(info.get("role") == "front", "front role", info)
    expect(info.get("shards_total") == 2, "front fleet size", info)
    shards = info.get("shards", [])
    expect(all(s.get("up") for s in shards), "links up", info)
    expect(
        [s.get("breaker") for s in shards] == ["closed", "closed"],
        "breakers start closed",
        info,
    )

    # 2. register: round-robin puts globals 0,2 on shard 0 and 1,3 on
    # shard 1 (setup replies 0/1 on shard 1 are before every fault
    # window, so registration is clean)
    reg = call(
        FRONT,
        {
            "proto": 2,
            "id": 1,
            "op": "register_index",
            "name": "chaos",
            "band": 1,
            "series": [[0, 0, 0], [5, 5, 5], [0.1, 0.1, 0.1], [4, 4, 4]],
            "labels": [0, 1, 0, 1],
        },
    )
    expect(reg.get("ok") is True, "register through front", reg)
    expect(reg.get("per_shard") == [2, 2], "round-robin split", reg)

    # 3. deadline propagation: shard 1's next reply sleeps 3 s, the
    # 500 ms budget must lose with the typed code and the budget echoed
    r = search(deadline_ms=500)
    expect(r.get("ok") is False, "deadline search fails", r)
    expect(r.get("code") == "deadline_exceeded", "typed deadline code", r)
    expect(r.get("budget_ms") == 500, "budget echoed", r)
    expect("neighbors" not in r, "no neighbor list on a failed reply", r)

    # 4. typed unavailable: the next reply is torn mid-line, the
    # reconnect retry is refused, and the second consecutive failure
    # opens the breaker (threshold 2)
    r = search()
    expect(r.get("ok") is False, "post-tear search fails", r)
    expect(r.get("code") == "unavailable", "typed unavailable code", r)
    expect(r.get("shards_ok") == 1, "1/2 shards answered", r)
    expect(r.get("shards_total") == 2, "fleet size on error", r)
    expect("neighbors" not in r, "never an unflagged subset", r)

    # 5. opt-in partial through the open breaker: exact over shard 0,
    # explicitly flagged (globals 0 and 2 both live on shard 0, so the
    # expected answer is checkable bit for bit)
    r = search(allow_partial=True)
    expect(r.get("ok") is True, "partial search succeeds", r)
    p = r.get("partial")
    expect(p is not None, "partial block present", r)
    expect(p.get("shards_ok") == 1 and p.get("shards_total") == 2, "partial health", r)
    expect(p.get("missing") == [1], "missing shard named", r)
    ns = r.get("neighbors", [])
    expect(len(ns) == 2, "k=2 neighbors over the survivor", r)
    expect(ns[0].get("dist") == 0 and ns[0].get("idx") == 0, "nearest is global 0", r)
    expect(ns[1].get("idx") == 2 and ns[1].get("dist") > 0, "runner-up is global 2", r)

    info = call(FRONT, {"op": "info"})
    expect(
        info["shards"][1].get("breaker") in ("open", "half_open"),
        "breaker tripped on shard 1",
        info,
    )

    # 6. self-healing: the probe thread burns through the refuse window
    # (12 events at 200 ms cadence) and closes the breaker on a verified
    # reconnect — no operator action, no restart
    recovered = False
    deadline = time.time() + 30
    while time.time() < deadline:
        info = call(FRONT, {"op": "info"})
        if info["shards"][1].get("breaker") == "closed":
            recovered = True
            break
        time.sleep(0.2)
    expect(recovered, "probe closes the breaker", info)

    r = search()
    expect(r.get("ok") is True, "full search after recovery", r)
    expect(r.get("shards_ok") == 2, "both shards answering", r)
    expect("partial" not in r, "no partial flag on a full merge", r)
    ns = r.get("neighbors", [])
    expect(len(ns) == 2 and ns[0].get("idx") == 0 and ns[1].get("idx") == 2,
           "exact merged answer after recovery", r)

    # 7. deadline_ms is validated, not clamped
    r = search(deadline_ms=0)
    expect(r.get("ok") is False and r.get("code") == "bad_request",
           "deadline_ms=0 rejected", r)

    # clean shutdown over the wire: front first, then both shards (the
    # refuse window is exhausted, so shard 1 accepts the connection)
    for addr in [FRONT, SHARD0, SHARD1]:
        r = call(addr, {"op": "shutdown"}, attempts=4)
        expect(r.get("ok") is True, f"shutdown {addr}", r)

    print(
        "chaos smoke OK: typed deadline_exceeded + unavailable + flagged "
        "partial, breaker opened and probe-recovered"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
